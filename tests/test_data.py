"""Data pipeline: determinism, prefetch, point generators."""
import numpy as np

from repro.configs import get_config
from repro.data import PrefetchingLoader, TokenPipeline, make_points


def test_pipeline_deterministic_per_step():
    cfg = get_config("qwen2-7b").reduced()
    p1 = TokenPipeline(cfg, batch=4, seq=32, seed=9)
    p2 = TokenPipeline(cfg, batch=4, seq=32, seed=9)
    for step in (0, 5, 1000):
        a, b = p1.global_batch(step), p2.global_batch(step)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        np.testing.assert_array_equal(a["labels"], b["labels"])
    # different steps differ
    assert not np.array_equal(p1.global_batch(0)["tokens"],
                              p1.global_batch(1)["tokens"])


def test_labels_are_shifted_tokens():
    cfg = get_config("qwen2-7b").reduced()
    p = TokenPipeline(cfg, batch=2, seq=16, seed=0,
                      corpus=np.arange(10_000, dtype=np.int32) % cfg.vocab)
    b = p.global_batch(3)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_prefetching_loader_orders_steps():
    cfg = get_config("musicgen-medium").reduced()
    p = TokenPipeline(cfg, batch=2, seq=8, seed=1)
    loader = PrefetchingLoader(p, None, start_step=0, depth=2)
    steps = [next(loader)[0] for _ in range(5)]
    loader.close()
    assert steps == [0, 1, 2, 3, 4]


def test_make_points_structure():
    pts, centers, assign = make_points(1000, 8, 10, seed=0)
    assert pts.shape == (1000, 8) and centers.shape == (10, 8)
    assert pts.dtype == np.float32
    # points sit near their generating centre
    d_own = np.linalg.norm(pts - centers[assign], axis=1)
    assert np.median(d_own) < 4.0
