"""Fault tolerance: restart-on-failure, determinism of replay,
straggler detection, end-to-end training driver."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import TokenPipeline
from repro.runtime import (FailureInjector, InjectedFailure, ResilientLoop,
                           StragglerWatchdog)
from repro.train.steps import init_train_state, make_train_step


@pytest.fixture(scope="module")
def tiny_setup():
    cfg = get_config("phi4-mini-3.8b").reduced()
    step_fn = jax.jit(make_train_step(cfg))
    state = init_train_state(jax.random.PRNGKey(0), cfg)
    pipeline = TokenPipeline(cfg, batch=2, seq=32, seed=0)
    return cfg, step_fn, state, pipeline


def _run(tmp_path, step_fn, state, pipeline, n, fail_at=()):
    loop = ResilientLoop(step_fn, pipeline, tmp_path, ckpt_every=4,
                         injector=FailureInjector(fail_at),
                         async_ckpt=False)
    final = loop.run(state, n)
    return loop, final


def test_failure_recovery_reaches_end(tmp_path, tiny_setup):
    cfg, step_fn, state, pipeline = tiny_setup
    loop, final = _run(tmp_path / "a", step_fn, state, pipeline, 12,
                       fail_at=(6, 9))
    assert loop.restarts == 2
    assert int(jax.device_get(final.step)) == 12


def test_recovery_is_bitwise_deterministic(tmp_path, tiny_setup):
    """Replay-after-failure must produce the same final params as a
    clean run (deterministic (seed, step) data + checkpointed state)."""
    cfg, step_fn, state, pipeline = tiny_setup
    _, clean = _run(tmp_path / "clean", step_fn, state, pipeline, 10)
    _, failed = _run(tmp_path / "failed", step_fn, state, pipeline, 10,
                     fail_at=(7,))
    for a, b in zip(jax.tree.leaves(clean.params),
                    jax.tree.leaves(failed.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_too_many_failures_raises(tmp_path, tiny_setup):
    cfg, step_fn, state, pipeline = tiny_setup
    loop = ResilientLoop(step_fn, pipeline, tmp_path / "b", ckpt_every=4,
                         injector=FailureInjector((3, 3)), max_restarts=0,
                         async_ckpt=False)
    # the same step fails again after restart -> exhausts budget
    loop.injector.seen = set()
    with pytest.raises(InjectedFailure):
        loop.run(state, 8)
        loop.injector.seen = set()
        loop.run(state, 8)


def test_straggler_watchdog_flags_slow_steps():
    wd = StragglerWatchdog(threshold=2.0)
    flags = [wd.observe(i, dt) for i, dt in
             enumerate([1.0, 1.1, 0.9, 5.0, 1.0, 1.05])]
    assert flags == [False, False, False, True, False, False]
    assert len(wd.events) == 1 and wd.events[0]["step"] == 3
    # EWMA not polluted by the straggler
    assert wd.ewma < 1.2


def test_loss_decreases_on_learnable_data(tmp_path):
    """End-to-end: a tiny model on a learnable bigram corpus must
    actually learn (loss drops materially)."""
    cfg = get_config("musicgen-medium").reduced()
    rng = np.random.default_rng(0)
    # deterministic cycle corpus: token t -> (t*7+3) % vocab
    seq = [0]
    for _ in range(20000):
        seq.append((seq[-1] * 7 + 3) % cfg.vocab)
    corpus = np.asarray(seq, dtype=np.int32)
    pipeline = TokenPipeline(cfg, batch=4, seq=64, seed=0, corpus=corpus)
    from repro.optim.adamw import AdamWConfig
    opt = AdamWConfig(lr_peak=1e-3, warmup_steps=10, decay_steps=80)
    step_fn = jax.jit(make_train_step(cfg, opt))
    state = init_train_state(jax.random.PRNGKey(1), cfg)
    loop = ResilientLoop(step_fn, pipeline, tmp_path / "lrn",
                         ckpt_every=1000, async_ckpt=False)
    loop.run(state, 80)
    losses = [m["loss"] for m in loop.metrics_log]
    assert np.mean(losses[-10:]) < 0.5 * np.mean(losses[:5])
