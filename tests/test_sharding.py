"""Partition-rule unit tests: every param leaf has a rule, specs match
tree structure, divisibility of sharded dims on the production shape."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, list_configs
from repro.launch.sharding import (batch_pspecs, cache_pspecs, param_pspecs,
                                   train_state_pspecs)
from repro.models.transformer import param_shapes

MESH_SHAPE = {"data": 16, "model": 16}


def _leaves_with_specs(cfg):
    shapes = param_shapes(cfg)
    specs = param_pspecs(cfg)
    flat_sh = jax.tree.leaves(shapes, is_leaf=lambda x: isinstance(x, tuple))
    flat_sp = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    return list(zip(flat_sh, flat_sp))


@pytest.mark.parametrize("arch", list_configs())
def test_every_param_has_rule_and_divides(arch):
    cfg = get_config(arch)
    pairs = _leaves_with_specs(cfg)
    assert pairs, "no params"
    for shape, spec in pairs:
        assert isinstance(spec, P)
        assert len(spec) <= len(shape)
        for dim, axis in zip(shape, spec):
            if axis is None:
                continue
            axes = axis if isinstance(axis, tuple) else (axis,)
            par = 1
            for a in axes:
                par *= MESH_SHAPE[a]
            assert dim % par == 0, \
                f"{arch}: dim {dim} not divisible by {par} ({spec})"


@pytest.mark.parametrize("arch", ["qwen2-7b", "qwen3-moe-235b-a22b",
                                  "mamba2-780m", "hymba-1.5b",
                                  "minicpm3-4b"])
def test_serve_tp_strips_data_axis(arch):
    cfg = get_config(arch)
    specs = jax.tree.leaves(param_pspecs(cfg, serve_tp=True),
                            is_leaf=lambda x: isinstance(x, P))
    for spec in specs:
        assert "data" not in [a for e in spec for a in
                              (e if isinstance(e, tuple) else (e,))
                              if e is not None]


def test_train_state_specs_mirror_params():
    cfg = get_config("phi4-mini-3.8b")
    ts = train_state_pspecs(cfg)
    assert ts.step == P()
    assert jax.tree.structure(ts.params, is_leaf=lambda x: isinstance(x, P)) \
        == jax.tree.structure(ts.m, is_leaf=lambda x: isinstance(x, P))


def test_cache_specs_batch_vs_seq_sharding():
    cfg = get_config("qwen2-7b")
    try:
        mesh = jax.sharding.AbstractMesh((16, 16), ("data", "model"))
    except TypeError:  # jax<=0.4.x: shape_tuple of (name, size) pairs
        mesh = jax.sharding.AbstractMesh((("data", 16), ("model", 16)))
    big = cache_pspecs(cfg, mesh, batch=128)
    small = cache_pspecs(cfg, mesh, batch=1)
    # batch >= data parallelism: batch dim sharded, seq on model
    assert big["k"][1] is not None
    # batch=1: seq spread over every axis
    assert small["k"][1] is None
    assert isinstance(small["k"][2], tuple)


def test_batch_2d_extends_axes():
    import dataclasses
    cfg = get_config("hymba-1.5b")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    b1 = batch_pspecs(cfg, mesh)
    b2 = batch_pspecs(dataclasses.replace(cfg, batch_2d=True), mesh)
    assert "model" not in b1["tokens"][0]
    assert "model" in b2["tokens"][0]
