"""Sample-weight support across the unified drivers.

Two contracts:

* UNIFORM PARITY — fitting with ``sample_weight=1`` is BIT-IDENTICAL
  to fitting without weights, on every backend and driver (the
  weighted program multiplies by exactly 1.0f, which is exact, so any
  divergence is a real defect in the weight threading).
* DUPLICATION ≡ INTEGER WEIGHTS — a dataset with each point repeated
  ``w`` times lands on the same fixed point as the unique points fit
  with integer weights ``w`` (the defining semantics of sample
  weights; summation order differs so parity is allclose, not bit).

The distributed (4/8-device) uniform-parity lane lives in
``tests/test_distributed.py`` (multidevice marker).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import KMeans, engine, kmeans_plusplus, lloyd, yinyang
from repro.data import make_points
from repro.streaming import StreamingKMeans

BACKENDS = ["oracle", "compact", "pallas", "lloyd"]


def _dataset(n, d, k, seed=0):
    pts, _, _ = make_points(n, d, k, seed=seed)
    pts = jnp.asarray(pts)
    init = kmeans_plusplus(jax.random.PRNGKey(seed + 1), pts, k)
    return pts, init


def _assert_bit_identical(r_a, r_b):
    assert int(r_a.n_iters) == int(r_b.n_iters)
    np.testing.assert_array_equal(np.asarray(r_a.assignments),
                                  np.asarray(r_b.assignments))
    assert float(r_a.inertia) == float(r_b.inertia)
    np.testing.assert_array_equal(np.asarray(r_a.centroids),
                                  np.asarray(r_b.centroids))


@pytest.mark.parametrize("backend", BACKENDS)
def test_uniform_weight_bit_parity_engine(backend):
    pts, init = _dataset(1000, 8, 12)
    kw = dict(n_groups=3, max_iters=50, tol=1e-5, backend=backend,
              interpret=True, tune="off")
    r0 = engine.fit(pts, init, **kw)
    r1 = engine.fit(pts, init, sample_weight=jnp.ones((1000,)), **kw)
    _assert_bit_identical(r0, r1)


def test_uniform_weight_bit_parity_large_bucketed_path():
    # large enough for the host-bucketed driver (weights ride through
    # every segment of the capacity-bucketed loop)
    pts, init = _dataset(6000, 16, 32)
    kw = dict(n_groups=3, max_iters=50, tol=1e-5, backend="compact",
              tune="off")
    r0 = engine.fit(pts, init, **kw)
    r1 = engine.fit(pts, init, sample_weight=jnp.ones((6000,)), **kw)
    _assert_bit_identical(r0, r1)


def test_uniform_weight_bit_parity_reference_paths():
    pts, init = _dataset(1500, 6, 9)
    ones = jnp.ones((1500,))
    _assert_bit_identical(lloyd(pts, init, 40, 1e-5),
                          lloyd(pts, init, 40, 1e-5, weights=ones))
    _assert_bit_identical(yinyang(pts, init, max_iters=40, tol=1e-5),
                          yinyang(pts, init, max_iters=40, tol=1e-5,
                                  weights=ones))


def test_uniform_weight_bit_parity_streaming():
    # the FIRST batch is fed unweighted to both so the cold-start
    # seeding is held fixed (weights now reach the k-means++ init,
    # where uniform weights select by a different sampler — the steps,
    # not the seeding, carry the bit-parity contract)
    pts, _, _ = make_points(2048, 8, 8, seed=2)
    sk_u = StreamingKMeans(8, seed=3)
    sk_w = StreamingKMeans(8, seed=3)
    sk_u.partial_fit(pts[:256], shard_id=0)
    sk_w.partial_fit(pts[:256], shard_id=0)
    for i in range(1, 8):
        b = pts[i * 256:(i + 1) * 256]
        sk_u.partial_fit(b, shard_id=i)
        sk_w.partial_fit(b, shard_id=i,
                         sample_weight=np.ones(len(b), np.float32))
    np.testing.assert_array_equal(sk_u.cluster_centers_,
                                  sk_w.cluster_centers_)
    np.testing.assert_array_equal(sk_u.counts_, sk_w.counts_)
    assert sk_u.stats_.distance_evals == sk_w.stats_.distance_evals
    assert sk_u.ewa_inertia_ == pytest.approx(sk_w.ewa_inertia_)


@pytest.mark.parametrize("backend", ["compact", "oracle"])
@pytest.mark.parametrize("seed", [0, 5])
def test_duplicated_points_equal_integer_weights(backend, seed):
    """The defining property of sample weights: repeating point i
    w_i times == weighting it w_i. Fixed points must agree (allclose:
    the summation orders differ)."""
    rng = np.random.default_rng(seed)
    base, _, _ = make_points(700, 6, 8, seed=seed)
    wts = rng.integers(1, 5, size=700)
    dup = np.repeat(base, wts, axis=0)
    init = kmeans_plusplus(jax.random.PRNGKey(seed + 1),
                           jnp.asarray(base), 8)
    kw = dict(max_iters=60, tol=1e-6, backend=backend, tune="off")
    r_w = engine.fit(jnp.asarray(base), init,
                     sample_weight=jnp.asarray(wts, jnp.float32), **kw)
    r_d = engine.fit(jnp.asarray(dup), init, **kw)
    np.testing.assert_allclose(np.asarray(r_w.centroids),
                               np.asarray(r_d.centroids), atol=1e-3)
    # the unique points' assignments agree with their duplicated copies
    offsets = np.concatenate([[0], np.cumsum(wts)[:-1]])
    np.testing.assert_array_equal(np.asarray(r_w.assignments),
                                  np.asarray(r_d.assignments)[offsets])
    np.testing.assert_allclose(float(r_w.inertia), float(r_d.inertia),
                               rtol=1e-4)


def test_duplicated_points_equal_integer_weights_lloyd_reference():
    rng = np.random.default_rng(11)
    base, _, _ = make_points(500, 4, 6, seed=11)
    wts = rng.integers(1, 4, size=500)
    dup = np.repeat(base, wts, axis=0)
    init = kmeans_plusplus(jax.random.PRNGKey(12), jnp.asarray(base), 6)
    r_w = lloyd(jnp.asarray(base), init, 60, 1e-6,
                weights=jnp.asarray(wts, jnp.float32))
    r_d = lloyd(jnp.asarray(dup), init, 60, 1e-6)
    np.testing.assert_allclose(np.asarray(r_w.centroids),
                               np.asarray(r_d.centroids), atol=1e-3)


def test_weighted_fits_agree_across_backends():
    """One non-uniform weighting, every backend: identical fixed point
    (the filters never see the weights, so the cross-backend exactness
    contract extends verbatim to weighted fits)."""
    pts, init = _dataset(900, 8, 10, seed=4)
    w = jnp.asarray(
        np.random.default_rng(4).uniform(0.25, 4.0, 900), jnp.float32)
    results = [engine.fit(pts, init, n_groups=3, max_iters=50, tol=1e-5,
                          backend=b, interpret=True, tune="off",
                          sample_weight=w)
               for b in BACKENDS]
    ref = results[0]
    for r in results[1:]:
        np.testing.assert_array_equal(np.asarray(r.assignments),
                                      np.asarray(ref.assignments))
        np.testing.assert_allclose(float(r.inertia), float(ref.inertia),
                                   rtol=1e-5)
    r_y = yinyang(pts, init, n_groups=3, max_iters=50, tol=1e-5,
                  weights=w)
    np.testing.assert_array_equal(np.asarray(r_y.assignments),
                                  np.asarray(ref.assignments))


def test_kmeans_api_weighted_surface():
    pts, _, _ = make_points(1200, 6, 8, seed=7)
    w = np.random.default_rng(7).uniform(0.5, 2.0, 1200).astype(
        np.float32)
    km = KMeans(n_clusters=8, engine="compact", seed=1, tune="off")
    labels = km.fit_predict(pts, sample_weight=w)
    np.testing.assert_array_equal(labels, km.labels_)
    # score is the negative weighted inertia of the training set
    s = km.score(pts, sample_weight=w)
    assert s == pytest.approx(-km.inertia_, rel=1e-4)
    # weights reach the seeding through the API, so a uniform-weight
    # fit is deterministic (bit-identical across calls) but draws its
    # init through the weighted sampler; engine-level uniform parity
    # with a SHARED init is covered above
    km_1 = KMeans(n_clusters=8, engine="compact", seed=1,
                  tune="off").fit(pts, sample_weight=np.ones(1200))
    km_2 = KMeans(n_clusters=8, engine="compact", seed=1,
                  tune="off").fit(pts, sample_weight=np.ones(1200))
    np.testing.assert_array_equal(km_1.labels_, km_2.labels_)
    assert km_1.inertia_ == km_2.inertia_


# -- weighted k-means++ seeding (weights reach init) -----------------------

def test_weighted_seeding_zero_weight_never_selected():
    """Zero-weight points must be invisible to the seeding: with the
    second half of the dataset at weight 0 (placed FAR away, where
    unweighted D^2 sampling would certainly pick them), every seeded
    centroid lies in the supported half."""
    rng = np.random.default_rng(0)
    near = rng.standard_normal((64, 3)).astype(np.float32)
    far = rng.standard_normal((64, 3)).astype(np.float32) + 100.0
    pts = jnp.asarray(np.concatenate([near, far]))
    w = jnp.asarray(np.concatenate([np.ones(64), np.zeros(64)]),
                    jnp.float32)
    for seed in range(5):
        c = np.asarray(kmeans_plusplus(jax.random.PRNGKey(seed), pts, 6,
                                       weights=w))
        assert np.all(np.abs(c) < 50.0), \
            f"zero-weight point seeded as a centroid (seed {seed})"


def test_weighted_seeding_first_draw_proportional_to_weights():
    """The first centroid is drawn ∝ w (k=1 isolates that draw):
    empirical frequencies over many keys match w/Σw."""
    pts = jnp.asarray(np.eye(4, 3, dtype=np.float32) * np.arange(
        1, 5, dtype=np.float32)[:, None])
    w = jnp.asarray([8.0, 4.0, 2.0, 2.0])
    keys = jax.random.split(jax.random.PRNGKey(0), 2000)
    first = jax.vmap(
        lambda k: kmeans_plusplus(k, pts, 1, weights=w)[0])(keys)
    # identify which of the 4 points each draw selected
    d = np.linalg.norm(np.asarray(first)[:, None] - np.asarray(pts)[None],
                       axis=-1)
    counts = np.bincount(d.argmin(1), minlength=4) / 2000
    np.testing.assert_allclose(counts, np.asarray(w) / float(w.sum()),
                               atol=0.05)


def test_weighted_seeding_duplication_distributional_parity():
    """Duplication ≡ integer weights for the SEEDING, distributionally:
    on well-separated clusters whose sizes are expressed either as
    duplicated points or as integer weights, both samplers pick one
    seed per cluster at (near-)equal rates. Draw-for-draw equality is
    impossible — the duplicated sample space has more indices — so the
    parity claim is over outcomes, which is the defining semantics."""
    rng = np.random.default_rng(3)
    centers = np.asarray([[0, 0], [40, 0], [0, 40]], np.float32)
    base = np.concatenate(
        [c + rng.standard_normal((20, 2)).astype(np.float32) * 0.1
         for c in centers])
    wts = rng.integers(1, 5, size=60)
    dup = np.repeat(base, wts, axis=0)

    def cluster_pick_rate(pts, weights, n_keys=60):
        hits = 0
        for s in range(n_keys):
            c = np.asarray(kmeans_plusplus(
                jax.random.PRNGKey(s), jnp.asarray(pts), 3,
                weights=weights))
            got = set(np.linalg.norm(
                c[:, None] - centers[None], axis=-1).argmin(1).tolist())
            hits += (got == {0, 1, 2})
        return hits / n_keys

    r_w = cluster_pick_rate(base, jnp.asarray(wts, jnp.float32))
    r_d = cluster_pick_rate(dup, None)
    assert r_w > 0.9 and r_d > 0.9
    assert abs(r_w - r_d) < 0.1


def test_streaming_weighted_cold_start_reaches_seeder():
    """The streaming cold start forwards buffered weights into the
    k-means++ init: zero-weight poison points far from the data never
    become centroids, even though they dominate unweighted D^2."""
    rng = np.random.default_rng(1)
    good = rng.standard_normal((96, 3)).astype(np.float32)
    poison = rng.standard_normal((32, 3)).astype(np.float32) + 200.0
    pts = np.concatenate([good, poison])
    w = np.concatenate([np.ones(96), np.zeros(32)]).astype(np.float32)
    skm = StreamingKMeans(4, init_size=128, seed=0)
    skm.partial_fit(pts, shard_id=0, sample_weight=w)
    assert skm.initialized
    assert np.all(np.abs(skm.cluster_centers_) < 100.0)


def test_streaming_weighted_counts_are_weight_mass():
    """Weighted streaming: the EMA's effective counts accumulate the
    WEIGHT MASS (not the row count), and doubling every weight doubles
    the mass without moving the centroids. The baseline feeds explicit
    weight-1.0 so both runs seed through the weighted sampler (uniform
    weights of ANY scale produce identical categorical draws — the
    logits shift is uniform)."""
    pts, _, _ = make_points(1024, 6, 4, seed=9)
    w1 = np.ones((256,), np.float32)
    w = np.full((256,), 2.0, np.float32)
    sk_1 = StreamingKMeans(4, seed=2, decay=1.0)
    sk_2 = StreamingKMeans(4, seed=2, decay=1.0)
    for i in range(4):
        b = pts[i * 256:(i + 1) * 256]
        sk_1.partial_fit(b, shard_id=i, sample_weight=w1)
        sk_2.partial_fit(b, shard_id=i, sample_weight=w)
    assert float(sk_2.counts_.sum()) == pytest.approx(
        2.0 * float(sk_1.counts_.sum()))
    np.testing.assert_allclose(sk_2.cluster_centers_,
                               sk_1.cluster_centers_, atol=1e-5)
