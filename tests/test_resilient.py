"""Resilient streaming fits: checkpoint/restore-replay chaos suite.

The contract under test (see ``repro/streaming/resilient.py`` and
``docs/fault_tolerance.md``): a streaming fit that crashes anywhere —
between batches, mid-batch with torn host state, before the first
checkpoint, or onto a corrupt checkpoint — restores and REPLAYS the
deterministic ``(seed, shard)`` stream to centroids / counts / drift
ledger BIT-IDENTICAL to an uninterrupted run. Elastic restores into a
grown/shrunk mesh keep every cached bound valid and land on the same
clustering up to psum re-association (inertia parity).

Fast single-device roundtrip/resume tests run in tier 1; the
failure-injection and forced-multi-device elastic tests carry the
``chaos`` marker and run in CI's chaos lane.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.data import PointStream
from repro.runtime.fault_tolerance import FailureInjector, InjectedFailure
from repro.streaming import StreamingKMeans

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _stream(seed=7, n_shards=4):
    return PointStream(shard_size=256, n_shards=n_shards, n_dims=8, k=8,
                       seed=seed)


def _assert_stream_state_equal(a: StreamingKMeans, b: StreamingKMeans):
    np.testing.assert_array_equal(a.cluster_centers_, b.cluster_centers_)
    np.testing.assert_array_equal(a.counts_, b.counts_)
    np.testing.assert_array_equal(a._ledger.centroid, b._ledger.centroid)
    np.testing.assert_array_equal(a._ledger.group, b._ledger.group)


# -- tier-1: save/restore roundtrip and resume -----------------------------

def test_save_restore_roundtrip_full_state(tmp_path):
    """Every piece of stream state survives the checkpoint: bound
    cache (entries, LRU order, scalars), float64 ledger (bit-exact —
    it must never transit a device), reseed reservoir, stats, tuned
    engine config. A restored estimator is indistinguishable going
    forward: the next batch produces bit-identical state."""
    stream = _stream()
    skm = StreamingKMeans(8, seed=1).fit_stream(stream, epochs=2)
    skm.save(tmp_path, step=8)
    got, step = StreamingKMeans.restore(tmp_path)
    assert step == 8
    _assert_stream_state_equal(skm, got)
    np.testing.assert_array_equal(skm._since_hit, got._since_hit)
    np.testing.assert_array_equal(skm._groups_np, got._groups_np)
    np.testing.assert_array_equal(skm.labels_, got.labels_)
    assert got._ledger.centroid.dtype == np.float64
    d1, d2 = skm.stats_.to_dict(), got.stats_.to_dict()
    for key in ("ckpt_saves", "restores"):   # legitimately differ
        d1.pop(key), d2.pop(key)
    assert d1 == d2
    assert skm.ewa_inertia_ == got.ewa_inertia_
    assert (skm.min_bucket, skm.chunk, skm._ggf) == \
        (got.min_bucket, got.chunk, got._ggf)
    assert len(skm._far) == len(got._far)
    for (u1, p1), (u2, p2) in zip(skm._far, got._far):
        assert u1 == u2
        np.testing.assert_array_equal(p1, p2)
    assert list(skm._cache._d.keys()) == list(got._cache._d.keys())
    for sid in skm._cache._d:
        e1, e2 = skm._cache._d[sid], got._cache._d[sid]
        np.testing.assert_array_equal(e1.assignments, e2.assignments)
        np.testing.assert_array_equal(e1.ub, e2.ub)
        np.testing.assert_array_equal(e1.lb, e2.lb)
        np.testing.assert_array_equal(e1.ub_off, e2.ub_off)
        np.testing.assert_array_equal(e1.gdrift_snap, e2.gdrift_snap)
        assert (e1.gmax, e1.ub_scale) == (e2.gmax, e2.ub_scale)
    # the restored estimator continues bit-identically
    skm.partial_fit(stream.shard(0), shard_id=0)
    got.partial_fit(stream.shard(0), shard_id=0)
    _assert_stream_state_equal(skm, got)
    assert skm.stats_.cache_hits == got.stats_.cache_hits


def test_save_requires_initialized(tmp_path):
    from repro.core import NotFittedError
    with pytest.raises(NotFittedError):
        StreamingKMeans(4).save(tmp_path, step=0)


def test_restore_rejects_wrong_format(tmp_path):
    from repro.checkpoint import save_checkpoint
    save_checkpoint(tmp_path, 1, [np.zeros((3,))], meta={"format": "other"})
    with pytest.raises(ValueError):
        StreamingKMeans.restore(tmp_path)


def test_resilient_requires_global_batch_source(tmp_path):
    with pytest.raises(ValueError):
        StreamingKMeans(4).fit_stream(
            [np.zeros((8, 3), np.float32)], resilient=True,
            ckpt_dir=tmp_path)
    with pytest.raises(ValueError):
        StreamingKMeans(4).fit_stream(_stream(), resilient=True)


def test_resume_across_runs_bit_exact(tmp_path):
    """Stop after 2 epochs (terminal checkpoint), resume a FRESH
    estimator for 4 — bit-identical to 4 uninterrupted epochs. This is
    the planned-restart path (the preemption story without the
    failure)."""
    stream = _stream(seed=9)
    sk_u = StreamingKMeans(8, seed=3).fit_stream(stream, epochs=4)

    sk_a = StreamingKMeans(8, seed=3)
    sk_a.fit_stream(stream, epochs=2, resilient=True, ckpt_dir=tmp_path,
                    ckpt_every=3)
    sk_b = StreamingKMeans(8, seed=3)   # new process, no memory of sk_a
    sk_b.fit_stream(stream, epochs=4, resilient=True, ckpt_dir=tmp_path,
                    ckpt_every=3)
    _assert_stream_state_equal(sk_u, sk_b)
    assert sk_b.stats_.restores == 1
    assert sk_b.stats_.replayed_batches == 0   # resumed, nothing replayed


def test_adopt_centroids_keeps_cached_bounds_valid():
    """Warm handover: adopted centroids enter the ledger as drift, so
    the stream continues on the old bound cache without violating a
    single triangle-inequality bound (finite, sane inertia)."""
    stream = _stream(seed=5)
    skm = StreamingKMeans(8, seed=2).fit_stream(stream, epochs=2)
    led_before = skm._ledger.centroid.copy()
    rng = np.random.default_rng(0)
    skm.adopt_centroids(skm.cluster_centers_
                        + rng.standard_normal((8, 8)).astype(np.float32))
    assert np.all(skm._ledger.centroid >= led_before)
    hits_before = skm.stats_.cache_hits
    skm.fit_stream(stream, epochs=1)
    assert skm.stats_.cache_hits > hits_before   # cache survived
    pts = np.concatenate([stream.shard(i) for i in range(4)])
    assert np.isfinite(skm.inertia_of(pts))


# -- chaos lane: failure injection -----------------------------------------

pytest_chaos = pytest.mark.chaos


@pytest_chaos
def test_restore_replay_bit_exact_after_crash(tmp_path):
    """The acceptance scenario: inject a failure mid-epoch, restore
    the async checkpoint, replay the deterministic stream — final
    centroids bit-identical to the uninterrupted run."""
    stream = _stream()
    sk_u = StreamingKMeans(8, seed=3).fit_stream(stream, epochs=3)
    inj = FailureInjector(fail_at=(7,))
    sk_r = StreamingKMeans(8, seed=3)
    sk_r.fit_stream(stream, epochs=3, resilient=True, ckpt_dir=tmp_path,
                    ckpt_every=3, injector=inj)
    assert inj.seen == {7}
    assert sk_r.stats_.restores == 1
    assert sk_r.stats_.replayed_batches >= 1
    _assert_stream_state_equal(sk_u, sk_r)


@pytest_chaos
def test_crash_mid_batch_torn_state_recovers(tmp_path):
    """Host crash MID-batch: the chaos hook fires after the device
    update landed but before the host commit (ledger/cache/stats), so
    the estimator is genuinely torn. Restore must discard the torn
    half-step and land bit-identical."""
    stream = _stream(seed=2)
    sk_u = StreamingKMeans(8, seed=1).fit_stream(stream, epochs=3)
    sk_r = StreamingKMeans(8, seed=1)
    fired = []

    def tear_once(est, sid):
        if est.stats_.batches == 8 and not fired:
            fired.append(sid)
            raise InjectedFailure("host died mid-batch")

    sk_r.chaos_hook = tear_once
    sk_r.fit_stream(stream, epochs=3, resilient=True, ckpt_dir=tmp_path,
                    ckpt_every=4)
    assert fired
    assert sk_r.stats_.restores == 1
    _assert_stream_state_equal(sk_u, sk_r)


@pytest_chaos
def test_failure_before_first_checkpoint_cold_restarts(tmp_path):
    """A stale/absent checkpoint directory: the failure lands before
    anything was saved (huge ckpt_every), so recovery is a cold
    restart replaying from step 0 — still bit-exact, because the cold
    start itself is (seed, shard)-deterministic."""
    stream = _stream(seed=4)
    sk_u = StreamingKMeans(8, seed=2).fit_stream(stream, epochs=2)
    inj = FailureInjector(fail_at=(5,))
    sk_r = StreamingKMeans(8, seed=2)
    sk_r.fit_stream(stream, epochs=2, resilient=True, ckpt_dir=tmp_path,
                    ckpt_every=1000, injector=inj)
    assert sk_r.stats_.restores == 1
    assert sk_r.stats_.replayed_batches == 5
    _assert_stream_state_equal(sk_u, sk_r)


@pytest_chaos
def test_corrupt_checkpoint_falls_back_and_replays(tmp_path):
    """Chaos on the STORAGE: the newest checkpoint is torn on disk.
    Recovery walks back to the previous complete save and replays the
    longer tail — bit-exact either way."""
    stream = _stream(seed=6)
    sk_u = StreamingKMeans(8, seed=5).fit_stream(stream, epochs=3)
    corrupted = []

    def corrupt_then_fail(est, sid):
        if est.stats_.batches == 9 and not corrupted:
            # tear the newest published step, then crash
            steps = sorted(p for p in os.listdir(tmp_path)
                           if p.startswith("step_"))
            with open(os.path.join(tmp_path, steps[-1], "shard_0.npz"),
                      "wb") as f:
                f.write(b"torn write")
            corrupted.append(steps[-1])
            raise InjectedFailure("crash onto corrupt checkpoint")

    sk_r = StreamingKMeans(8, seed=5)
    sk_r.chaos_hook = corrupt_then_fail
    sk_r.fit_stream(stream, epochs=3, resilient=True, ckpt_dir=tmp_path,
                    ckpt_every=3, async_ckpt=False)
    assert corrupted
    assert sk_r.stats_.restores == 1
    _assert_stream_state_equal(sk_u, sk_r)


@pytest_chaos
def test_shard_dropout_stream_keeps_going(tmp_path):
    """A shard's host drops out of the stream after a restore: the fit
    continues on the surviving shards (the lost shard's cached bounds
    just age in the LRU; its centroids keep living off other shards'
    points), stays finite, and reseeding patience is epoch-scaled so
    nothing is spuriously killed."""
    stream = _stream(seed=8)
    skm = StreamingKMeans(8, seed=1)
    skm.fit_stream(stream, epochs=2, resilient=True, ckpt_dir=tmp_path,
                   ckpt_every=4)
    got, step = StreamingKMeans.restore(tmp_path)
    assert step == 8
    surviving = [s for s in range(4) if s != 2]
    for epoch in range(2):
        for s in surviving:
            got.partial_fit(stream.shard(s), shard_id=s)
    pts = np.concatenate([stream.shard(i) for i in range(4)])
    assert np.isfinite(got.inertia_of(pts))
    assert got.stats_.batches == 8 + 6


@pytest_chaos
def test_multiple_failures_within_budget(tmp_path):
    stream = _stream(seed=12)
    sk_u = StreamingKMeans(8, seed=7).fit_stream(stream, epochs=4)
    inj = FailureInjector(fail_at=(3, 9, 13))
    sk_r = StreamingKMeans(8, seed=7)
    sk_r.fit_stream(stream, epochs=4, resilient=True, ckpt_dir=tmp_path,
                    ckpt_every=2, injector=inj, max_restarts=5)
    assert sk_r.stats_.restores == 3
    _assert_stream_state_equal(sk_u, sk_r)


@pytest_chaos
def test_restart_budget_exhausted_raises(tmp_path):
    stream = _stream(seed=1)
    inj = FailureInjector(fail_at=(2, 3, 4))
    with pytest.raises(InjectedFailure):
        StreamingKMeans(8, seed=1).fit_stream(
            stream, epochs=2, resilient=True, ckpt_dir=tmp_path,
            ckpt_every=2, injector=inj, max_restarts=2)


@pytest_chaos
def test_recovery_metrics_published(tmp_path):
    """ckpt_*/restore_*/replay_* observability: the registry sees the
    saves, the restore and the replayed batches, and the event log
    carries ckpt_save/restore events."""
    from repro.obs.metrics import MetricsRegistry
    reg = MetricsRegistry()
    stream = _stream(seed=3)
    inj = FailureInjector(fail_at=(7,))   # off the ckpt lattice: replay
    skm = StreamingKMeans(8, seed=2, obs=reg)
    skm.fit_stream(stream, epochs=2, resilient=True, ckpt_dir=tmp_path,
                   ckpt_every=2, injector=inj)
    m = reg.to_dict()
    assert m["ckpt_saves_total"] >= 2
    assert m["restore_total"] == 1
    assert m["replay_batches_total"] >= 1
    events = [e["event"] for e in reg.events]
    assert "ckpt_save" in events and "restore" in events


# -- chaos lane: elastic resize (forced multi-device subprocesses) ---------

def _run_forced(body: str, devices: int = 4) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = \
        f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                         env=env, capture_output=True, text=True,
                         timeout=560)
    assert out.returncode == 0, f"STDOUT:{out.stdout}\nSTDERR:{out.stderr}"
    return out.stdout


@pytest_chaos
def test_elastic_grow_2_to_4(tmp_path):
    """Checkpoint under a 2-shard mesh, restore into a 4-shard mesh
    (and single-device): batches re-pad into the new lattice, cached
    bounds stay valid, and the final clustering matches the
    uninterrupted 2-shard run with inertia parity. Same-topology
    recovery stays bit-exact."""
    _run_forced(f"""
        import tempfile, numpy as np, jax
        from repro.core.distributed import make_mesh
        from repro.data import PointStream
        from repro.runtime.fault_tolerance import FailureInjector
        from repro.streaming import StreamingKMeans
        assert len(jax.devices()) == 4
        stream = PointStream(shard_size=256, n_shards=4, n_dims=8, k=8,
                             seed=11)
        pts = np.concatenate([stream.shard(i) for i in range(4)])
        mesh2 = make_mesh(2)

        sk_full = StreamingKMeans(8, seed=1, mesh=mesh2)
        sk_full.fit_stream(stream, epochs=3)
        ref = sk_full.inertia_of(pts)

        # same-topology crash recovery: bit-exact
        d = {str(tmp_path)!r}
        inj = FailureInjector(fail_at=(9,))
        sk_r = StreamingKMeans(8, seed=1, mesh=mesh2)
        sk_r.fit_stream(stream, epochs=3, resilient=True, ckpt_dir=d,
                        ckpt_every=4, injector=inj)
        assert np.array_equal(sk_full.cluster_centers_,
                              sk_r.cluster_centers_)
        assert np.array_equal(sk_full.counts_, sk_r.counts_)

        # elastic grow: the step-8 checkpoint re-pads into 4 shards
        sk_g, step = StreamingKMeans.restore(d, step=8, mesh=make_mesh(4))
        assert step == 8
        for s in range(8, 12):
            b = stream.global_batch(s)
            sk_g.partial_fit(b["points"], shard_id=b["shard_id"])
        got = sk_g.inertia_of(pts)
        assert abs(got - ref) / ref < 0.02, (got, ref)
        assert sk_g.stats_.cache_hits >= 8   # tail revisits hit the cache

        # and into a single device (mesh=None)
        sk_s, step = StreamingKMeans.restore(d, step=8)
        for s in range(8, 12):
            b = stream.global_batch(s)
            sk_s.partial_fit(b["points"], shard_id=b["shard_id"])
        got_s = sk_s.inertia_of(pts)
        assert abs(got_s - ref) / ref < 0.02, (got_s, ref)
        print("grow OK", ref, got, got_s)
    """)


@pytest_chaos
def test_elastic_shrink_4_to_2(tmp_path):
    """The preemption direction: checkpoint under 4 shards, lose two
    hosts, restore into a 2-shard mesh and finish — inertia parity
    with the uninterrupted 4-shard run."""
    _run_forced(f"""
        import numpy as np, jax
        from repro.core.distributed import make_mesh
        from repro.data import PointStream
        from repro.streaming import StreamingKMeans
        assert len(jax.devices()) == 4
        stream = PointStream(shard_size=256, n_shards=4, n_dims=8, k=8,
                             seed=13)
        pts = np.concatenate([stream.shard(i) for i in range(4)])
        mesh4 = make_mesh(4)

        sk_full = StreamingKMeans(8, seed=2, mesh=mesh4)
        sk_full.fit_stream(stream, epochs=3)
        ref = sk_full.inertia_of(pts)

        d = {str(tmp_path)!r}
        sk_a = StreamingKMeans(8, seed=2, mesh=mesh4)
        sk_a.fit_stream(stream, epochs=2, resilient=True, ckpt_dir=d,
                        ckpt_every=4)
        sk_b, step = StreamingKMeans.restore(d, mesh=make_mesh(2))
        assert step == 8
        for s in range(8, 12):
            b = stream.global_batch(s)
            sk_b.partial_fit(b["points"], shard_id=b["shard_id"])
        got = sk_b.inertia_of(pts)
        assert abs(got - ref) / ref < 0.02, (got, ref)
        print("shrink OK", ref, got)
    """)
