"""Distributed behaviour on a multi-device (forced 8-CPU) runtime.

jax locks the device count at first init, so these tests run in
subprocesses with XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""
import os
import subprocess
import sys
import textwrap

import pytest

# every test here spawns a forced-multi-device subprocess — CI runs
# them in the dedicated multi-device lane
pytestmark = pytest.mark.multidevice

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(body: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    script = textwrap.dedent(body)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, f"STDOUT:{out.stdout}\nSTDERR:{out.stderr}"
    return out.stdout


def test_sharded_compact_parity_matrix():
    """The tentpole contract: the capacity-bucketed compaction inside
    the shard_map body is EXACT — bit-identical assignments/inertia to
    the sharded masked-dense oracle (same psum reduction order), with
    and without int8 partial-sums compression, and it matches the
    single-device engine's fixed point; psum'd distance_evals show the
    per-shard filter actually skipping work."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import distributed_yinyang, engine_fit, \\
            kmeans_plusplus
        from repro.data import make_points
        pts_np, _, _ = make_points(4096, 32, 64, seed=0)
        pts = jnp.asarray(pts_np)
        init = kmeans_plusplus(jax.random.PRNGKey(1), pts, 64)
        mesh = jax.make_mesh((8,), ("data",))
        kw = dict(max_iters=40, tol=1e-5)

        for compress in (False, True):
            r_d = distributed_yinyang(pts, init, mesh, backend="dense",
                                      compress=compress, **kw)
            r_c = distributed_yinyang(pts, init, mesh, backend="compact",
                                      compress=compress, **kw)
            assert np.array_equal(np.asarray(r_d.assignments),
                                  np.asarray(r_c.assignments)), compress
            assert float(r_d.inertia) == float(r_c.inertia), compress
            assert int(r_d.n_iters) == int(r_c.n_iters), compress

        r_c = distributed_yinyang(pts, init, mesh, backend="compact", **kw)
        r_s = engine_fit(pts, init, backend="compact", tune="off", **kw)
        assert np.array_equal(np.asarray(r_c.assignments),
                              np.asarray(r_s.assignments))
        np.testing.assert_allclose(float(r_c.inertia), float(r_s.inertia),
                                   rtol=1e-5)
        np.testing.assert_allclose(np.asarray(r_c.centroids),
                                   np.asarray(r_s.centroids), atol=1e-4)
        # work-efficiency: psum'd evals beat the dense equivalent
        dense_equiv = 4096 * 64 * (int(r_c.n_iters) + 1)
        assert float(r_c.distance_evals) < dense_equiv, \\
            (float(r_c.distance_evals), dense_equiv)
        print("PARITY-MATRIX-OK")
    """)


def test_sharded_compact_uneven_and_all_survivor_shards():
    """Uneven N (sentinel padding) and a pathological shard whose
    points never filter (uniform noise -> every point a candidate ->
    that shard rides the TOP capacity bucket while the clustered
    shards downshift): shard-divergent bucket levels must not desync
    the collectives or perturb the fixed point."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import distributed_yinyang, engine_fit, \\
            kmeans_plusplus
        from repro.data import make_points
        mesh = jax.make_mesh((8,), ("data",))
        kw = dict(max_iters=40, tol=1e-5)

        # uneven: N=4001 over 8 shards (pad rows are sentinels)
        pts_np, _, _ = make_points(4001, 16, 24, seed=3)
        pts = jnp.asarray(pts_np)
        init = kmeans_plusplus(jax.random.PRNGKey(1), pts, 24)
        r_c = distributed_yinyang(pts, init, mesh, backend="compact", **kw)
        r_s = engine_fit(pts, init, backend="compact", tune="off", **kw)
        assert r_c.assignments.shape == (4001,)
        assert np.array_equal(np.asarray(r_c.assignments),
                              np.asarray(r_s.assignments))
        np.testing.assert_allclose(float(r_c.inertia), float(r_s.inertia),
                                   rtol=1e-5)

        # all-survivors shard: shard 0 = structureless uniform noise
        # (bounds never prune it), shards 1..7 = tight clusters
        rng = np.random.default_rng(7)
        clustered, _, _ = make_points(3584, 16, 24, seed=4,
                                      cluster_std=0.3)
        noise = rng.uniform(-20, 20, size=(512, 16)).astype(np.float32)
        pts = jnp.asarray(np.concatenate([noise, clustered], axis=0))
        init = kmeans_plusplus(jax.random.PRNGKey(2), pts, 24)
        r_d = distributed_yinyang(pts, init, mesh, backend="dense", **kw)
        r_c = distributed_yinyang(pts, init, mesh, backend="compact", **kw)
        assert np.array_equal(np.asarray(r_d.assignments),
                              np.asarray(r_c.assignments))
        assert float(r_d.inertia) == float(r_c.inertia)
        print("UNEVEN-SURVIVOR-OK")
    """)


def test_sharded_streaming_matches_local():
    """StreamingKMeans(mesh=...): the distributed partial_fit (psum'd
    batch sums/counts feeding the decayed EMA) matches the local step
    on counts and distance evals exactly, and on centroids to psum
    rounding; uneven batches exercise the sentinel padding."""
    _run("""
        import jax, numpy as np
        from repro.streaming import StreamingKMeans
        from repro.data import PointStream
        mesh = jax.make_mesh((8,), ("data",))
        # 997 % 8 != 0 -> every batch pads
        stream = PointStream(shard_size=997, n_shards=4, n_dims=16, k=8,
                             seed=3)
        sk_l = StreamingKMeans(8, seed=5)
        sk_d = StreamingKMeans(8, seed=5, mesh=mesh)
        sk_l.fit_stream(stream, epochs=3)
        sk_d.fit_stream(stream, epochs=3)
        assert sk_d.stats_.sharded_batches == sk_d.stats_.batches > 0
        assert sk_d.stats_.cache_hits == sk_l.stats_.cache_hits > 0
        # the psum'd EMA differs from the local one by summation-order
        # rounding, so margin-riding filter decisions may flip: evals
        # agree to ~1%, effective counts to a few points, the total
        # effective mass exactly
        el, ed = sk_l.stats_.distance_evals, sk_d.stats_.distance_evals
        assert abs(el - ed) <= 0.02 * el, (el, ed)
        assert float(sk_d.counts_.sum()) == float(sk_l.counts_.sum())
        np.testing.assert_allclose(sk_d.counts_, sk_l.counts_, atol=8)
        np.testing.assert_allclose(sk_d.cluster_centers_,
                                   sk_l.cluster_centers_, atol=1e-3)
        full = np.concatenate([stream.shard(s) for s in range(4)], 0)
        i_l, i_d = sk_l.inertia_of(full), sk_d.inertia_of(full)
        assert abs(i_l - i_d) <= 1e-4 * max(i_l, 1.0)
        # the PrefetchingLoader/global_batch protocol drives the same
        # sharded step
        sk_g = StreamingKMeans(8, seed=5, mesh=mesh)
        sk_g.fit_stream([stream.global_batch(s) for s in range(4)])
        assert sk_g.stats_.sharded_batches == 4
        print("SHARDED-STREAM-OK")
    """)


def test_sharded_fit_adopts_tuned_shard_config():
    """make_fit_sharded(tune=): a tuned entry stored under the
    shard-count signature steers the compact body's capacities, and the
    result stays exact (tuning is wall-clock-only, also in the
    distributed engine)."""
    _run("""
        import os, jax, jax.numpy as jnp, numpy as np
        os.environ["REPRO_KMEANS_TUNE_CACHE"] = "/tmp/dist_tune.json"
        import repro.tune as tune
        tune.set_default_cache(None)
        from repro.core import distributed_yinyang, kmeans_plusplus
        from repro.core.engine import EngineConfig
        from repro.data import make_points
        pts_np, _, _ = make_points(4096, 16, 24, seed=0)
        pts = jnp.asarray(pts_np)
        init = kmeans_plusplus(jax.random.PRNGKey(1), pts, 24)
        mesh = jax.make_mesh((8,), ("data",))
        kw = dict(max_iters=30, tol=1e-5)
        r_ref = distributed_yinyang(pts, init, mesh, tune="off", **kw)
        # per-shard n = 512; store a deliberately odd sharded config
        cfg = EngineConfig(min_cap=64, chunk=1024, down_n=4,
                           refresh_in_pass=True)
        sig = tune.signature(512, 24, 16, shards=8)
        assert sig.endswith("|s8")
        tune.default_cache().store(sig, cfg, ms=1.0)
        assert tune.lookup(n=512, k=24, d=16, shards=8) == cfg
        r_tuned = distributed_yinyang(pts, init, mesh, tune="auto", **kw)
        assert np.array_equal(np.asarray(r_ref.assignments),
                              np.asarray(r_tuned.assignments))
        np.testing.assert_allclose(float(r_ref.inertia),
                                   float(r_tuned.inertia), rtol=1e-6)
        print("SHARD-TUNE-OK")
    """)


def test_sharded_weighted_parity():
    """sample_weight through the unified sharded drivers: uniform
    weights are bit-identical to the unweighted fit (dense AND
    compact), and a non-uniform weighting matches the single-device
    weighted engine bit-for-bit — one weight implementation behind
    every reducer."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import distributed_yinyang, engine_fit, \\
            kmeans_plusplus
        from repro.data import make_points
        pts_np, _, _ = make_points(4096, 16, 24, seed=0)
        pts = jnp.asarray(pts_np)
        init = kmeans_plusplus(jax.random.PRNGKey(1), pts, 24)
        mesh = jax.make_mesh((8,), ("data",))
        kw = dict(max_iters=40, tol=1e-5)

        ones = jnp.ones((4096,), jnp.float32)
        for backend in ("dense", "compact"):
            r0 = distributed_yinyang(pts, init, mesh, backend=backend,
                                     **kw)
            r1 = distributed_yinyang(pts, init, mesh, backend=backend,
                                     sample_weight=ones, **kw)
            assert np.array_equal(np.asarray(r0.assignments),
                                  np.asarray(r1.assignments)), backend
            assert float(r0.inertia) == float(r1.inertia), backend
            assert int(r0.n_iters) == int(r1.n_iters), backend

        w = jnp.asarray(np.random.default_rng(0).integers(
            1, 4, size=4096).astype(np.float32))
        r_d = distributed_yinyang(pts, init, mesh, backend="compact",
                                  sample_weight=w, **kw)
        r_s = engine_fit(pts, init, backend="compact", tune="off",
                         sample_weight=w, **kw)
        assert np.array_equal(np.asarray(r_d.assignments),
                              np.asarray(r_s.assignments))
        np.testing.assert_allclose(float(r_d.inertia),
                                   float(r_s.inertia), rtol=1e-5)
        # uneven N + weights: pad rows get weight 0 and drop out
        pts_u = pts[:4001]
        init_u = kmeans_plusplus(jax.random.PRNGKey(2), pts_u, 24)
        r_du = distributed_yinyang(pts_u, init_u, mesh,
                                   backend="compact",
                                   sample_weight=w[:4001], **kw)
        r_su = engine_fit(pts_u, init_u, backend="compact", tune="off",
                          sample_weight=w[:4001], **kw)
        assert np.array_equal(np.asarray(r_du.assignments),
                              np.asarray(r_su.assignments))
        # weighted sharded streaming: uniform weights == unweighted.
        # The first batch seeds the cold start, and explicit weights
        # route it through the weighted k-means++ sampler (a different
        # program than the unweighted one) — feed it unweighted to
        # BOTH so the comparison holds seeding fixed and exercises the
        # weighted EMA steps.
        from repro.streaming import StreamingKMeans
        from repro.data import PointStream
        stream = PointStream(shard_size=997, n_shards=4, n_dims=16,
                             k=8, seed=3)
        sk_u = StreamingKMeans(8, seed=5, mesh=mesh)
        sk_w = StreamingKMeans(8, seed=5, mesh=mesh)
        for step, (sid, b) in enumerate(stream.batches(2)):
            sk_u.partial_fit(b, shard_id=sid)
            sk_w.partial_fit(b, shard_id=sid,
                             sample_weight=None if step == 0 else
                             np.ones(len(b), np.float32))
        np.testing.assert_array_equal(sk_u.cluster_centers_,
                                      sk_w.cluster_centers_)
        assert float(sk_u.counts_.sum()) == float(sk_w.counts_.sum())
        print("WEIGHTED-SHARDED-OK")
    """)


def test_sharded_autotune_measures_through_the_sharded_driver():
    """tune.autotune(shards=S) with no injected measure drives the
    REAL distributed_yinyang under shard_map (the ROADMAP remainder:
    |sS signatures from sharded measurement, not single-device
    fallback) — and the stored winner steers a subsequent
    distributed_yinyang(tune='auto') without changing its result."""
    _run("""
        import os, jax, jax.numpy as jnp, numpy as np
        os.environ["REPRO_KMEANS_TUNE_CACHE"] = "/tmp/dist_tune_m.json"
        import repro.tune as tune
        tune.set_default_cache(None)
        tune.default_cache().clear()
        from repro.core import distributed_yinyang, kmeans_plusplus
        from repro.data import make_points
        pts_np, _, _ = make_points(4096, 8, 16, seed=1)
        pts = jnp.asarray(pts_np)
        init = kmeans_plusplus(jax.random.PRNGKey(1), pts, 16)
        # one shard's worth (512 points), measured over 8 real devices
        cfg = tune.autotune(pts[:512], init, n_groups=2, max_iters=15,
                            shards=8, max_rounds=1, max_measurements=5,
                            repeats=1)
        sig = tune.signature(512, 16, 8, shards=8)
        assert sig.endswith("|s8")
        assert tune.default_cache().lookup(sig) == cfg
        assert cfg.backend == "compact"   # no Lloyd grid on sharded keys
        entry = tune.default_cache().entry(sig)
        assert entry["measured"] >= 1 and entry["ms"] > 0
        assert "lloyd_ms" not in entry
        mesh = jax.make_mesh((8,), ("data",))
        kw = dict(max_iters=30, tol=1e-5)
        r_off = distributed_yinyang(pts, init, mesh, tune="off", **kw)
        r_tuned = distributed_yinyang(pts, init, mesh, tune="auto", **kw)
        assert np.array_equal(np.asarray(r_off.assignments),
                              np.asarray(r_tuned.assignments))
        print("SHARDED-MEASURE-OK")
    """)


def test_distributed_kmeans_matches_single_device():
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import yinyang, distributed_yinyang, kmeans_plusplus
        from repro.data import make_points
        pts_np, _, _ = make_points(4096, 16, 24, seed=0)
        pts = jnp.asarray(pts_np)
        init = kmeans_plusplus(jax.random.PRNGKey(1), pts, 24)
        mesh = jax.make_mesh((8,), ("data",))
        r_d = distributed_yinyang(pts, init, mesh, axes=("data",),
                                  max_iters=40, tol=1e-5)
        r_s = yinyang(pts, init, max_iters=40, tol=1e-5)
        np.testing.assert_allclose(np.asarray(r_d.centroids),
                                   np.asarray(r_s.centroids), atol=1e-3)
        np.testing.assert_allclose(float(r_d.inertia), float(r_s.inertia),
                                   rtol=1e-4)
        print("DIST-KMEANS-OK")
    """)


def test_distributed_kmeans_compressed_psum_converges():
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import distributed_yinyang, yinyang, kmeans_plusplus
        from repro.data import make_points
        pts_np, _, _ = make_points(4096, 8, 16, seed=2)
        pts = jnp.asarray(pts_np)
        init = kmeans_plusplus(jax.random.PRNGKey(1), pts, 16)
        mesh = jax.make_mesh((8,), ("data",))
        r_c = distributed_yinyang(pts, init, mesh, compress=True,
                                  max_iters=40, tol=1e-5)
        r_s = yinyang(pts, init, max_iters=40, tol=1e-5)
        # int8 psum is approximate: inertia within 1%
        assert abs(float(r_c.inertia) - float(r_s.inertia)) \
            <= 0.01 * float(r_s.inertia)
        print("COMPRESSED-OK")
    """)


def test_sharded_train_step_runs_and_matches_unsharded():
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.train.steps import init_train_state, make_train_step
        from repro.launch.sharding import (train_state_pspecs, batch_pspecs,
                                           named)
        cfg = get_config("qwen2-7b").reduced()
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        step = make_train_step(cfg)
        state = init_train_state(jax.random.PRNGKey(0), cfg)
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                              (8, 32), 0, cfg.vocab),
                 "labels": jax.random.randint(jax.random.PRNGKey(2),
                                              (8, 32), 0, cfg.vocab)}
        # unsharded reference
        _, m_ref = jax.jit(step)(state, batch)
        with mesh:
            st_sh = named(mesh, train_state_pspecs(cfg))
            b_sh = named(mesh, batch_pspecs(cfg, mesh))
            state_s = jax.device_put(state, st_sh)
            batch_s = jax.device_put(batch, b_sh)
            _, m_sh = jax.jit(step, in_shardings=(st_sh, b_sh),
                              out_shardings=(st_sh, None))(state_s, batch_s)
        np.testing.assert_allclose(float(m_ref["loss"]),
                                   float(m_sh["loss"]), rtol=2e-3)
        print("SHARDED-TRAIN-OK")
    """)


def test_elastic_restore_to_different_mesh():
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.train.steps import init_train_state
        from repro.launch.sharding import train_state_pspecs, named
        from repro.checkpoint import save_checkpoint, restore_checkpoint
        import tempfile
        cfg = get_config("phi4-mini-3.8b").reduced()
        state = init_train_state(jax.random.PRNGKey(0), cfg)
        mesh_a = jax.make_mesh((8, 1), ("data", "model"))
        mesh_b = jax.make_mesh((2, 4), ("data", "model"))
        with tempfile.TemporaryDirectory() as d:
            state_a = jax.device_put(state, named(mesh_a,
                                                  train_state_pspecs(cfg)))
            save_checkpoint(d, 1, state_a)
            like = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
            restored, step = restore_checkpoint(
                d, like, shardings=named(mesh_b, train_state_pspecs(cfg)))
            for a, b in zip(jax.tree.leaves(state_a),
                            jax.tree.leaves(restored)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        print("ELASTIC-OK")
    """)


def test_reduced_dryrun_lowers_on_8_devices():
    """The dry-run machinery itself (lower+compile+cost) on a reduced
    config and a small mesh — fast proxy for the production sweep."""
    _run("""
        import jax
        from repro.configs import get_config
        from repro.launch.sharding import (train_state_pspecs, batch_pspecs,
                                           named)
        from repro.train.steps import make_train_step, init_train_state
        import functools, jax.numpy as jnp
        cfg = get_config("hymba-1.5b").reduced()
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        step = make_train_step(cfg)
        state = jax.eval_shape(functools.partial(init_train_state, cfg=cfg),
                               jax.ShapeDtypeStruct((2,), jnp.uint32))
        batch = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
        with mesh:
            lowered = jax.jit(
                step,
                in_shardings=(named(mesh, train_state_pspecs(cfg)),
                              named(mesh, batch_pspecs(cfg, mesh))),
                out_shardings=(named(mesh, train_state_pspecs(cfg)), None),
            ).lower(state, batch)
            compiled = lowered.compile()
            cost = compiled.cost_analysis()
            if isinstance(cost, list):  # jax<=0.4.x returns [dict]
                cost = cost[0]
            assert cost.get("flops", 0) > 0
        print("DRYRUN-8DEV-OK")
    """)

def test_distributed_stats_rings_skew_and_watchdog():
    """Observability under real sharding: per-shard rings survive the
    shard_map (one (R, C) ring per shard), the global evals invariant
    reconciles exactly against the psum'd EvalCount, the skew gauge
    reflects a deliberately imbalanced shard (uniform noise on shard 0
    -> it does several times the median work -> the StragglerWatchdog
    flags exactly that shard), and obs on/off stays bit-identical."""
    _run("""
        import json
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import distributed_yinyang, kmeans_plusplus
        from repro.data import make_points
        from repro.obs import MetricsRegistry
        from repro.obs.ring import COL_EVALS
        from repro.runtime.fault_tolerance import StragglerWatchdog

        mesh = jax.make_mesh((8,), ("data",))
        kw = dict(n_groups=6, max_iters=30, tol=1e-5, backend="compact")

        # balanced fit first: parity + invariant + serializable stats
        pts_np, _, _ = make_points(4096, 16, 24, seed=0)
        pts = jnp.asarray(pts_np)
        init = kmeans_plusplus(jax.random.PRNGKey(1), pts, 24)
        r_off = distributed_yinyang(pts, init, mesh, **kw)
        reg = MetricsRegistry()
        r_on, st = distributed_yinyang(pts, init, mesh,
                                       return_stats=True, obs=reg, **kw)
        assert np.array_equal(np.asarray(r_off.assignments),
                              np.asarray(r_on.assignments))
        assert float(r_off.inertia) == float(r_on.inertia)
        assert st.shard_rings.shape[0] == 8
        assert st.ring.shape[0] == int(r_on.n_iters) + 1
        total = st.init_evals + float(np.sum(st.ring[:, COL_EVALS]))
        assert total == float(r_on.distance_evals), (total,
            float(r_on.distance_evals))
        json.dumps(st.to_dict())
        assert [e for e in reg.events if e["event"] == "distributed_fit"]

        # imbalanced fit: shard 0 = structureless uniform noise (its
        # bounds never prune -> far more evals than the median shard)
        rng = np.random.default_rng(7)
        clustered, _, _ = make_points(3584, 16, 24, seed=4,
                                      cluster_std=0.3)
        noise = rng.uniform(-20, 20, size=(512, 16)).astype(np.float32)
        pts = jnp.asarray(np.concatenate([noise, clustered], axis=0))
        init = kmeans_plusplus(jax.random.PRNGKey(2), pts, 24)
        wd = StragglerWatchdog(threshold=1.6)
        _, st = distributed_yinyang(pts, init, mesh, return_stats=True,
                                    watchdog=wd, **kw)
        assert float(np.max(st.shard_skew)) > 1.5, st.shard_skew
        assert wd.events, "noise shard never flagged"
        assert all(e["shard"] == 0 for e in wd.events), wd.events
        print("DIST-OBS-OK")
    """)
