"""Distributed behaviour on a multi-device (forced 8-CPU) runtime.

jax locks the device count at first init, so these tests run in
subprocesses with XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(body: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    script = textwrap.dedent(body)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, f"STDOUT:{out.stdout}\nSTDERR:{out.stderr}"
    return out.stdout


def test_distributed_kmeans_matches_single_device():
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import yinyang, distributed_yinyang, kmeans_plusplus
        from repro.data import make_points
        pts_np, _, _ = make_points(4096, 16, 24, seed=0)
        pts = jnp.asarray(pts_np)
        init = kmeans_plusplus(jax.random.PRNGKey(1), pts, 24)
        mesh = jax.make_mesh((8,), ("data",))
        r_d = distributed_yinyang(pts, init, mesh, axes=("data",),
                                  max_iters=40, tol=1e-5)
        r_s = yinyang(pts, init, max_iters=40, tol=1e-5)
        np.testing.assert_allclose(np.asarray(r_d.centroids),
                                   np.asarray(r_s.centroids), atol=1e-3)
        np.testing.assert_allclose(float(r_d.inertia), float(r_s.inertia),
                                   rtol=1e-4)
        print("DIST-KMEANS-OK")
    """)


def test_distributed_kmeans_compressed_psum_converges():
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import distributed_yinyang, yinyang, kmeans_plusplus
        from repro.data import make_points
        pts_np, _, _ = make_points(4096, 8, 16, seed=2)
        pts = jnp.asarray(pts_np)
        init = kmeans_plusplus(jax.random.PRNGKey(1), pts, 16)
        mesh = jax.make_mesh((8,), ("data",))
        r_c = distributed_yinyang(pts, init, mesh, compress=True,
                                  max_iters=40, tol=1e-5)
        r_s = yinyang(pts, init, max_iters=40, tol=1e-5)
        # int8 psum is approximate: inertia within 1%
        assert abs(float(r_c.inertia) - float(r_s.inertia)) \
            <= 0.01 * float(r_s.inertia)
        print("COMPRESSED-OK")
    """)


def test_sharded_train_step_runs_and_matches_unsharded():
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.train.steps import init_train_state, make_train_step
        from repro.launch.sharding import (train_state_pspecs, batch_pspecs,
                                           named)
        cfg = get_config("qwen2-7b").reduced()
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        step = make_train_step(cfg)
        state = init_train_state(jax.random.PRNGKey(0), cfg)
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                              (8, 32), 0, cfg.vocab),
                 "labels": jax.random.randint(jax.random.PRNGKey(2),
                                              (8, 32), 0, cfg.vocab)}
        # unsharded reference
        _, m_ref = jax.jit(step)(state, batch)
        with mesh:
            st_sh = named(mesh, train_state_pspecs(cfg))
            b_sh = named(mesh, batch_pspecs(cfg, mesh))
            state_s = jax.device_put(state, st_sh)
            batch_s = jax.device_put(batch, b_sh)
            _, m_sh = jax.jit(step, in_shardings=(st_sh, b_sh),
                              out_shardings=(st_sh, None))(state_s, batch_s)
        np.testing.assert_allclose(float(m_ref["loss"]),
                                   float(m_sh["loss"]), rtol=2e-3)
        print("SHARDED-TRAIN-OK")
    """)


def test_elastic_restore_to_different_mesh():
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.train.steps import init_train_state
        from repro.launch.sharding import train_state_pspecs, named
        from repro.checkpoint import save_checkpoint, restore_checkpoint
        import tempfile
        cfg = get_config("phi4-mini-3.8b").reduced()
        state = init_train_state(jax.random.PRNGKey(0), cfg)
        mesh_a = jax.make_mesh((8, 1), ("data", "model"))
        mesh_b = jax.make_mesh((2, 4), ("data", "model"))
        with tempfile.TemporaryDirectory() as d:
            state_a = jax.device_put(state, named(mesh_a,
                                                  train_state_pspecs(cfg)))
            save_checkpoint(d, 1, state_a)
            like = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
            restored, step = restore_checkpoint(
                d, like, shardings=named(mesh_b, train_state_pspecs(cfg)))
            for a, b in zip(jax.tree.leaves(state_a),
                            jax.tree.leaves(restored)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        print("ELASTIC-OK")
    """)


def test_reduced_dryrun_lowers_on_8_devices():
    """The dry-run machinery itself (lower+compile+cost) on a reduced
    config and a small mesh — fast proxy for the production sweep."""
    _run("""
        import jax
        from repro.configs import get_config
        from repro.launch.sharding import (train_state_pspecs, batch_pspecs,
                                           named)
        from repro.train.steps import make_train_step, init_train_state
        import functools, jax.numpy as jnp
        cfg = get_config("hymba-1.5b").reduced()
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        step = make_train_step(cfg)
        state = jax.eval_shape(functools.partial(init_train_state, cfg=cfg),
                               jax.ShapeDtypeStruct((2,), jnp.uint32))
        batch = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
        with mesh:
            lowered = jax.jit(
                step,
                in_shardings=(named(mesh, train_state_pspecs(cfg)),
                              named(mesh, batch_pspecs(cfg, mesh))),
                out_shardings=(named(mesh, train_state_pspecs(cfg)), None),
            ).lower(state, batch)
            compiled = lowered.compile()
            cost = compiled.cost_analysis()
            if isinstance(cost, list):  # jax<=0.4.x returns [dict]
                cost = cost[0]
            assert cost.get("flops", 0) > 0
        print("DRYRUN-8DEV-OK")
    """)
