"""The serving subsystem: swap consistency, bucket discipline, drift-
gated table reuse, the serve knob family, and engine lifecycle."""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pairwise_sq_dists
from repro.core import engine as _engine
from repro.core.distances import row_norms_sq
from repro.obs import MetricsRegistry
from repro.serve import CentroidIndex, ServeEngine
from repro.tune import (ServeConfig, TuneCache, autotune_serve,
                        lookup_serve, serve_signature)


def _dense_labels(q, centroids):
    return np.asarray(jnp.argmin(
        pairwise_sq_dists(jnp.asarray(q), jnp.asarray(centroids)), axis=1))


def _mk(n, d, seed):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n, d)).astype(np.float32)


# -- swap consistency: the acceptance criterion --------------------------


def test_swap_consistency_exactly_one_epoch():
    """Under a concurrent publisher, every response's labels must match
    the dense oracle of ITS OWN epoch exactly — a batch that mixed two
    epochs could not satisfy any single epoch's oracle (the centroid
    sets are independent draws, so their label maps differ)."""
    d, k = 8, 16
    q = _mk(4096, d, 0)
    pub_rng = np.random.default_rng(1)
    c0 = _mk(k, d, 2)
    epoch_centroids = {1: c0}
    idx = CentroidIndex(c0)
    stop = threading.Event()

    def publisher():
        while not stop.is_set():
            c = pub_rng.standard_normal((k, d)).astype(np.float32)
            ep = idx.publish(c)
            epoch_centroids[ep] = c
            time.sleep(0.001)

    cfg = ServeConfig(min_bucket=64, max_batch=1024)
    req_rng = np.random.default_rng(3)
    results = []
    with ServeEngine(idx, config=cfg, tune="off") as eng:
        eng.assign(q[:64])              # compile before the clock
        t = threading.Thread(target=publisher)
        t.start()
        try:
            for _ in range(100):
                m = int(req_rng.integers(16, 600))
                lo = int(req_rng.integers(0, q.shape[0] - m))
                results.append((lo, m, eng.assign(q[lo:lo + m])))
                time.sleep(0.001)
        finally:
            stop.set()
            t.join()

    epochs = set()
    for lo, m, (labels, epoch) in results:
        assert labels.shape == (m,)
        ref = _dense_labels(q[lo:lo + m], epoch_centroids[epoch])
        assert np.array_equal(labels, ref), \
            f"labels mixed epochs (claimed epoch {epoch})"
        epochs.add(epoch)
    # the publisher really swapped mid-traffic, so the parity above
    # exercised more than one epoch
    assert len(epochs) > 1


# -- bucket lattice: ragged traffic must not recompile --------------------


def test_bucket_reuse_no_recompile():
    # distinctive (d, k): the serve jits are module-level, so their
    # program cache is shared across tests — unique shapes make the
    # compile-count deltas below attributable to THIS test's buckets
    d, k = 12, 20
    q = _mk(1024, d, 0)
    idx = CentroidIndex(_mk(k, d, 1))
    cfg = ServeConfig(min_bucket=256, max_batch=1024)
    with ServeEngine(idx, config=cfg, tune="off") as eng:
        eng.assign(q[:300])             # bucket 512: compile
        (fn,) = eng._assigns.values()
        n0 = fn.cache_size()
        for m in (257, 400, 511, 512):  # all land in bucket 512
            labels, _ = eng.assign(q[:m])
            assert labels.shape == (m,)
        assert fn.cache_size() == n0, "ragged sizes recompiled"
        eng.assign(q[:600])             # bucket 1024: one new program
        assert fn.cache_size() == n0 + 1


# -- drift-gated table rebuild vs reuse -----------------------------------


def test_index_reuses_tables_under_drift_threshold():
    k, d = 16, 8
    c = _mk(k, d, 0)
    idx = CentroidIndex(rebuild_threshold=0.05)
    # the first publish must carry drift info too — it sets the
    # baseline the reuse decision is measured against
    idx.publish(c, cum_drift=np.zeros(k))
    s1 = idx.acquire()
    assert (idx.publishes, idx.rebuilds, idx.reuses) == (1, 1, 0)

    # tiny cumulative drift since that baseline: tables REUSED (same
    # objects)
    drift = np.full(k, 1e-4)
    idx.publish(c + 1e-4, cum_drift=drift)
    s2 = idx.acquire()
    assert s2.epoch == 2 and s2.tables_epoch == s1.epoch
    assert s2.members is s1.members and s2.groups is s1.groups
    assert idx.reuses == 1

    # large drift: rebuild, tables stamped with the new epoch
    idx.publish(c * 3.0, cum_drift=drift + 100.0)
    s3 = idx.acquire()
    assert s3.tables_epoch == s3.epoch == 3
    assert idx.rebuilds == 2

    # no drift information -> always rebuild (the safe default)
    idx.publish(c)
    assert idx.rebuilds == 3
    # force_rebuild wins even under tiny drift
    idx.publish(c, cum_drift=np.zeros(k), force_rebuild=True)
    assert idx.rebuilds == 4


def test_index_acquire_before_publish_raises():
    idx = CentroidIndex()
    assert not idx.ready
    with pytest.raises(RuntimeError):
        idx.acquire()


# -- every serve backend is exact ----------------------------------------


@pytest.mark.parametrize("backend", ["fused", "grouped", "pallas"])
def test_make_serve_assign_backends_exact(backend):
    k, d = 32, 8
    q = _mk(512, d, 0)
    centroids = _mk(k, d, 1)
    cj = jnp.asarray(centroids)
    c2 = row_norms_sq(cj)
    groups, members, gsize = _engine.build_assign_tables(cj)
    fn = _engine.make_serve_assign((k, int(gsize.shape[0])),
                                   backend=backend, chunk=256,
                                   interpret=True)
    labels = np.asarray(fn(jnp.asarray(q), cj, c2, groups, members,
                           gsize))
    assert np.array_equal(labels, _dense_labels(q, centroids))


def test_make_serve_assign_unknown_backend():
    with pytest.raises(ValueError):
        _engine.make_serve_assign((8, 2), backend="nope")


# -- engine lifecycle -----------------------------------------------------


def test_engine_empty_request():
    idx = CentroidIndex(_mk(4, 8, 0))
    with ServeEngine(idx, config=ServeConfig(), tune="off") as eng:
        labels, epoch = eng.assign(np.zeros((0, 8), np.float32))
        assert labels.shape == (0,) and epoch == 1


def test_engine_jumbo_request_split_and_exact():
    """A request larger than max_batch is split internally; the caller
    sees one future with the full concatenated labels."""
    d, k = 8, 16
    q = _mk(1300, d, 0)
    centroids = _mk(k, d, 1)
    idx = CentroidIndex(centroids)
    cfg = ServeConfig(min_bucket=64, max_batch=512)
    with ServeEngine(idx, config=cfg, tune="off") as eng:
        labels, epoch = eng.assign(q)
        assert labels.shape == (1300,) and epoch == 1
        assert np.array_equal(labels, _dense_labels(q, centroids))


def test_engine_device_resident_submit_exact():
    """A device-resident f32 jax.Array block skips host staging (the
    exact-fit path feeds it straight to the jitted assign) and yields
    the same labels as the numpy route."""
    d, k = 8, 16
    q = _mk(512, d, 3)
    centroids = _mk(k, d, 1)
    idx = CentroidIndex(centroids)
    cfg = ServeConfig(min_bucket=64, max_batch=512)
    with ServeEngine(idx, config=cfg, tune="off") as eng:
        labels_np, _ = eng.assign(q)
        labels_dev, epoch = eng.assign(jnp.asarray(q))
        assert epoch == 1
        assert np.array_equal(labels_dev, labels_np)
        assert np.array_equal(labels_dev, _dense_labels(q, centroids))
        # jumbo device-resident blocks split on device, same contract
        big = jnp.asarray(_mk(1300, d, 4))
        labels, _ = eng.assign(big)
        assert labels.shape == (1300,)
        assert np.array_equal(labels,
                              _dense_labels(np.asarray(big), centroids))
        # non-f32 device input falls back to the host coercion path
        labels16, _ = eng.assign(jnp.asarray(q, dtype=jnp.float16))
        assert labels16.shape == (512,)


def test_engine_submit_requires_running():
    idx = CentroidIndex(_mk(4, 8, 0))
    eng = ServeEngine(idx, config=ServeConfig(), tune="off")
    with pytest.raises(RuntimeError):
        eng.submit(np.zeros((4, 8), np.float32))


def test_engine_stop_before_publish_fails_pending():
    idx = CentroidIndex()                 # nothing ever published
    eng = ServeEngine(idx, config=ServeConfig(), tune="off").start()
    fut = eng.submit(np.zeros((4, 8), np.float32))
    eng.stop()
    with pytest.raises(RuntimeError):
        fut.result(timeout=30)


def test_engine_stop_before_publish_fails_split_jumbo():
    """A jumbo (split) request must also fail — not hang — when the
    engine stops with no published centroids: the part futures carry
    the exception, and the split must propagate it to the user future
    (``f.result()`` inside ``add_done_callback`` would be swallowed)."""
    idx = CentroidIndex()
    cfg = ServeConfig(min_bucket=64, max_batch=128)
    eng = ServeEngine(idx, config=cfg, tune="off").start()
    fut = eng.submit(_mk(300, 8, 0))      # 3 parts
    eng.stop()
    with pytest.raises(RuntimeError):
        fut.result(timeout=30)


def test_engine_submit_rejects_wrong_feature_dim():
    """A wrong-D block must be rejected synchronously at submit — on
    the serve thread it would fail mid-batch (and before the loop was
    hardened, kill the thread)."""
    idx = CentroidIndex(_mk(8, 16, 0))
    with ServeEngine(idx, config=ServeConfig(), tune="off") as eng:
        with pytest.raises(ValueError, match="feature dim"):
            eng.submit(_mk(4, 8, 1))
        labels, _ = eng.assign(_mk(4, 16, 2))   # engine still serves
        assert labels.shape == (4,)


def test_engine_thread_survives_batch_error():
    """A backend failure inside one batch must fail THAT batch's
    futures and leave the serve thread alive for the next request —
    not die silently and hang every later submit."""
    d, k = 8, 16
    idx = CentroidIndex(_mk(k, d, 0))
    cfg = ServeConfig(min_bucket=64, max_batch=512)
    with ServeEngine(idx, config=cfg, tune="off") as eng:
        orig = eng._resolve_assign

        def boom(*a, **kw):
            raise RuntimeError("injected backend failure")

        eng._resolve_assign = boom
        with pytest.raises(RuntimeError, match="injected"):
            eng.submit(_mk(16, d, 1)).result(timeout=30)
        eng._resolve_assign = orig
        labels, _ = eng.assign(_mk(16, d, 2))
        assert labels.shape == (16,)


def test_engine_client_device_array_never_donated(monkeypatch):
    """The exact-fit fast path hands the CLIENT'S jax.Array to the
    jitted assign; off-CPU it must resolve the non-donating variant
    (donation would invalidate the caller's buffer in place), while
    engine-staged numpy batches keep donation. Simulated off-CPU via
    the backend probe; on real CPU donation is a no-op either way."""
    d, k = 8, 16
    q = _mk(512, d, 3)
    centroids = _mk(k, d, 1)
    idx = CentroidIndex(centroids)
    cfg = ServeConfig(min_bucket=64, max_batch=512)
    with ServeEngine(idx, config=cfg, tune="off") as eng:
        monkeypatch.setattr(jax, "default_backend", lambda: "gpu")
        qd = jnp.asarray(q)
        labels_dev, _ = eng.assign(qd)          # exact-fit client array
        labels_np, _ = eng.assign(q[:300])      # staged numpy batch
        assert {key[2] for key in eng._assigns} == {False, True}
        # the client's buffer stays usable after serving
        assert np.array_equal(np.asarray(qd), q)
        assert np.array_equal(labels_dev, _dense_labels(q, centroids))
        assert np.array_equal(labels_np,
                              _dense_labels(q[:300], centroids))


def test_engine_config_not_pinned_before_first_publish(monkeypatch):
    """A submit racing the first publish must not permanently cache the
    default config: the tuned ``serve|`` entry (which needs the
    snapshot's k/d) must still win once centroids exist."""
    import repro.serve.engine as se
    tuned = ServeConfig(max_batch=2048, chunk=512)
    monkeypatch.setattr(se, "lookup_serve", lambda **kw: tuned)
    idx = CentroidIndex()
    eng = ServeEngine(idx, tune="on")
    assert eng._config() == se.DEFAULT_SERVE_CONFIG
    assert eng._cfg is None               # fallback was NOT memoized
    idx.publish(_mk(8, 8, 0))
    assert eng._config() == tuned


def test_engine_counts_and_metrics():
    d, k = 8, 16
    q = _mk(2048, d, 0)
    reg = MetricsRegistry()
    idx = CentroidIndex(_mk(k, d, 1), obs=reg)
    cfg = ServeConfig(min_bucket=256, max_batch=1024)
    with ServeEngine(idx, config=cfg, tune="off", obs=reg) as eng:
        eng.assign(q[:300])
        eng.assign(q[:900])
        idx.publish(_mk(k, d, 2))
        _, epoch = eng.assign(q[:100])
        assert epoch == 2
        assert eng.batches == 3 and eng.points == 1300
        assert eng.epoch_swaps == 1
    text = reg.to_prometheus()
    for name in ("serve_batches_total", "serve_points_total",
                 "serve_epoch_swaps_total", "serve_batch_fill",
                 "serve_latency_seconds", "serve_publishes_total",
                 "serve_epoch"):
        assert name in text, f"missing metric {name}"


# -- the serve knob family ------------------------------------------------


def test_serve_config_roundtrip_and_tolerance():
    cfg = ServeConfig(backend="grouped", chunk=512).replace(max_batch=2048)
    assert ServeConfig.from_dict(cfg.to_dict()) == cfg
    # unknown keys from a newer writer are ignored, not fatal
    assert ServeConfig.from_dict(
        {**cfg.to_dict(), "future_knob": 1}) == cfg


def test_serve_signature_shape():
    sig = serve_signature(64, 32, platform="cpu")
    assert sig == "serve|cpu|k64|d32"


def test_autotune_serve_stores_and_lookup_finds(tmp_path):
    cache = TuneCache(str(tmp_path / "tc.json"))
    assert lookup_serve(k=8, d=4, cache=cache) is None
    cfg = autotune_serve(k=8, d=4, backends=["fused"], chunks=(256,),
                         max_batch=512, repeats=1, cache=cache)
    assert cfg.backend == "fused" and cfg.chunk == 256
    got = lookup_serve(k=8, d=4, cache=cache)
    assert got == cfg
