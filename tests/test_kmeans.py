"""Core K-means behaviour: exactness of the multi-level filters.

The central claim of the paper's algorithm layer: the triangle-
inequality filters NEVER change the result — only the work. So filtered
K-means must match Lloyd bit-for-bit (same assignments, same centroids)
while doing strictly fewer distance evaluations.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (KMeans, group_centroids, kmeans_plusplus, lloyd,
                        random_init, yinyang)
from repro.data import make_points


def _dataset(n=3000, d=12, k=16, seed=0):
    pts, _, _ = make_points(n, d, k, seed=seed)
    init = kmeans_plusplus(jax.random.PRNGKey(seed + 1), jnp.asarray(pts), k)
    return jnp.asarray(pts), init, k


@pytest.mark.parametrize("n_groups", [1, 4, None])
def test_filtered_matches_lloyd_exactly(n_groups):
    pts, init, k = _dataset()
    r_l = lloyd(pts, init, max_iters=50, tol=1e-5)
    r_f = yinyang(pts, init, n_groups=n_groups, max_iters=50, tol=1e-5)
    assert int(r_l.n_iters) == int(r_f.n_iters)
    np.testing.assert_array_equal(np.asarray(r_l.assignments),
                                  np.asarray(r_f.assignments))
    np.testing.assert_allclose(np.asarray(r_l.centroids),
                               np.asarray(r_f.centroids), atol=1e-4)


def test_filters_reduce_work():
    pts, init, k = _dataset(n=6000, k=32)
    r_l = lloyd(pts, init, max_iters=50, tol=1e-5)
    r_h = yinyang(pts, init, n_groups=1, max_iters=50, tol=1e-5)
    r_y = yinyang(pts, init, max_iters=50, tol=1e-5)
    assert float(r_h.distance_evals) < float(r_l.distance_evals)
    assert float(r_y.distance_evals) < float(r_h.distance_evals)
    # clustered data after warmup should prune the large majority
    assert float(r_y.distance_evals) < 0.5 * float(r_l.distance_evals)


def test_inertia_monotone_nonincreasing_across_iters():
    pts, init, k = _dataset(n=2000, k=8, seed=3)
    prev = None
    for iters in (1, 2, 4, 8):
        r = lloyd(pts, init, max_iters=iters, tol=0.0)
        val = float(r.inertia)
        if prev is not None:
            assert val <= prev + 1e-3
        prev = val


def test_kmeans_plusplus_beats_random_init():
    pts, _, k = _dataset(n=4000, d=8, k=24, seed=5)
    key = jax.random.PRNGKey(7)
    init_pp = kmeans_plusplus(key, pts, k)
    init_rand = random_init(key, pts, k)
    r_pp = lloyd(pts, init_pp, max_iters=1, tol=0.0)
    r_rand = lloyd(pts, init_rand, max_iters=1, tol=0.0)
    assert float(r_pp.inertia) < float(r_rand.inertia)


def test_group_centroids_partition():
    c = jax.random.normal(jax.random.PRNGKey(0), (40, 6))
    g = group_centroids(c, 5)
    assert g.shape == (40,)
    assert int(g.min()) >= 0 and int(g.max()) < 5


def test_sklearn_style_api():
    pts, _, _ = _dataset(n=1500, k=8)
    km = KMeans(n_clusters=8, algorithm="yinyang", seed=1).fit(pts)
    km_l = KMeans(n_clusters=8, algorithm="lloyd", seed=1).fit(pts)
    assert km.labels_.shape == (1500,)
    assert km.cluster_centers_.shape == (8, pts.shape[1])
    np.testing.assert_allclose(km.inertia_, km_l.inertia_, rtol=1e-5)
    assert km.distance_evals_ < km_l.distance_evals_
    pred = km.predict(pts[:10])
    np.testing.assert_array_equal(pred, km.labels_[:10])


def test_sklearn_parity_predict_transform_score():
    """The sklearn-parity inference surface on top of the tiled
    assign: transform is the (N, K) distance space, predict its
    argmin, score the negative inertia, fit_predict the training
    labels."""
    pts, _, k = _dataset(n=2000, k=8, seed=2)
    km = KMeans(n_clusters=8, seed=1, engine="compact",
                tune="off").fit(pts)
    T = km.transform(pts[:300])
    assert T.shape == (300, 8)
    d_ref = np.linalg.norm(np.asarray(pts[:300])[:, None]
                           - np.asarray(km.cluster_centers_)[None],
                           axis=-1)
    np.testing.assert_allclose(T, d_ref, atol=1e-3)
    np.testing.assert_array_equal(km.predict(pts[:300]), d_ref.argmin(1))
    # score == -inertia on the training set
    assert km.score(pts) == pytest.approx(-km.inertia_, rel=1e-4)
    km2 = KMeans(n_clusters=8, seed=1, engine="compact", tune="off")
    np.testing.assert_array_equal(km2.fit_predict(pts), km.labels_)


def test_predict_tiled_beyond_one_tile():
    """predict runs tiled (ragged N >> tile) and still matches the
    dense argmin — the no-O(N*K)-buffer contract of the new path."""
    pts, _, _ = _dataset(n=20000, k=12, seed=4)
    km = KMeans(n_clusters=12, seed=1, engine="compact",
                tune="off").fit(pts)
    got = km.predict(pts)
    ref = np.linalg.norm(
        np.asarray(pts)[:, None]
        - np.asarray(km.cluster_centers_)[None], axis=-1).argmin(1)
    np.testing.assert_array_equal(got, ref)


def test_empty_cluster_keeps_previous_centroid():
    # two far blobs, k=3: one centroid starts far away and owns nothing
    pts = jnp.concatenate([
        jnp.ones((50, 2)), -jnp.ones((50, 2))])
    init = jnp.asarray([[1.0, 1.0], [-1.0, -1.0], [100.0, 100.0]])
    r = lloyd(pts, init, max_iters=5, tol=1e-6)
    assert np.isfinite(np.asarray(r.centroids)).all()
    r_y = yinyang(pts, init, n_groups=1, max_iters=5, tol=1e-6)
    np.testing.assert_array_equal(np.asarray(r.assignments),
                                  np.asarray(r_y.assignments))


def test_distance_evals_counter_is_precision_safe():
    """Regression: a bare fp32 accumulator silently drops increments
    once the total passes 2^24 (one paper-scale iteration adds N*K ~
    10^8). The compensated EvalCount pair must keep exact integer
    counts far beyond that."""
    from repro.core import EvalCount

    naive = jnp.float32(2 ** 24)
    c = EvalCount.of(2 ** 24)
    for _ in range(64):
        naive = naive + jnp.float32(1.0)
        c = c.add(1.0)
    assert float(naive) == 2 ** 24          # the bug: +1 x64 vanished
    assert float(c.total()) == 2 ** 24 + 64

    # paper-scale accumulation: 50 iterations of N*K = 2^27 evals
    c = EvalCount.of(0)
    for _ in range(50):
        c = c.add(jnp.float32(2 ** 27))
    assert float(c.total()) == 50 * 2 ** 27

    # odd increments force rounding on almost every add; the (hi, lo)
    # pair must still hold the exact integer (total() rounds once)
    @jax.jit
    def accumulate(c0):
        def body(_, c):
            return c.add(2 ** 24 - 1)
        return jax.lax.fori_loop(0, 100, body, c0)
    c = accumulate(EvalCount.of(0))
    exact = np.float64(np.asarray(c.hi)) + np.float64(np.asarray(c.lo))
    assert exact == 100 * (2 ** 24 - 1)


def test_compact_path_matches_lloyd():
    from repro.core import yinyang_compact
    pts, init, k = _dataset(n=4000, k=24, seed=7)
    r_l = lloyd(pts, init, max_iters=40, tol=1e-5)
    r_c = yinyang_compact(pts, init, max_iters=40, tol=1e-5)
    np.testing.assert_allclose(float(r_l.inertia), float(r_c.inertia),
                               rtol=1e-5)
    agree = (np.asarray(r_l.assignments) ==
             np.asarray(r_c.assignments)).mean()
    assert agree > 0.999  # fp-tie divergence only
    assert float(r_c.distance_evals) < float(r_l.distance_evals)
