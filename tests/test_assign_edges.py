"""Edge cases of the tiled exact assign (``engine.assign``) — the
predict/serve hot path must stay exact off the happy path."""
import jax.numpy as jnp
import numpy as np

from repro.core import pairwise_sq_dists
from repro.core import engine as _engine


def _dense_labels(q, centroids):
    return np.asarray(jnp.argmin(
        pairwise_sq_dists(jnp.asarray(q), jnp.asarray(centroids)), axis=1))


def _mk(n, d, seed):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n, d)).astype(np.float32)


def test_assign_empty_batch():
    centroids = _mk(8, 4, 0)
    labels, dists = _engine.assign(np.zeros((0, 4), np.float32),
                                   centroids)
    assert labels.shape == (0,) and labels.dtype == jnp.int32
    assert dists.shape == (0,)


def test_assign_n_not_tile_multiple():
    """N that is neither a tile_n multiple nor a pow2 — the tail tile
    must still be exact."""
    q = _mk(1000, 8, 1)
    centroids = _mk(16, 8, 2)
    labels, _ = _engine.assign(q, centroids, tile_n=256)
    assert labels.shape == (1000,)
    assert np.array_equal(np.asarray(labels), _dense_labels(q, centroids))


def test_assign_k_equals_one():
    q = _mk(300, 8, 3)
    centroids = _mk(1, 8, 4)
    labels, dists = _engine.assign(q, centroids)
    assert np.array_equal(np.asarray(labels), np.zeros(300, np.int32))
    # dists are Euclidean (the Yinyang bound convention), not squared
    ref = np.sqrt(np.sum((q - centroids[0]) ** 2, axis=1))
    assert np.allclose(np.asarray(dists), ref, rtol=1e-4, atol=1e-4)


def test_assign_single_group():
    """n_groups=1 degenerates the candidate pass to the dense sweep —
    still exact."""
    q = _mk(700, 8, 5)
    centroids = _mk(24, 8, 6)
    labels, _ = _engine.assign(q, centroids, n_groups=1)
    assert np.array_equal(np.asarray(labels), _dense_labels(q, centroids))


def test_serve_fused_tail_not_chunk_multiple():
    """The fused serve kernel's lax.map tiling only engages on exact
    chunk multiples; any other size must fall back to one tile and
    stay exact."""
    for n in (48, 1536):                 # < chunk, and 1.5x chunk
        q = _mk(n, 8, 7)
        centroids = _mk(16, 8, 8)
        cj = jnp.asarray(centroids)
        from repro.core.distances import row_norms_sq
        labels = np.asarray(_engine.serve_assign_fused(
            jnp.asarray(q), cj, row_norms_sq(cj), chunk=1024))
        assert np.array_equal(labels, _dense_labels(q, centroids))
