"""Streaming / mini-batch subsystem: parity, bound validity, lifecycle.

Three contracts:

* CONVERGENCE — ``partial_fit`` over all shards of a dataset lands
  within a bounded inertia gap of the batch engine fit (the subsystem's
  acceptance metric), while doing measurably less distance work than a
  dense mini-batch pass thanks to the carried bounds.
* SOUNDNESS — the drift-inflated bounds (``inflate_bounds``) remain
  true triangle-inequality bounds under arbitrary centroid drift
  sequences (property test): a violated bound would silently skip a
  nearer centroid, so this is the invariant everything rests on.
* LIFECYCLE — NotFittedError before enough data, deterministic shard
  streams, decay semantics, reseeding, KMeans.partial_fit delegation.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import KMeans, NotFittedError, engine, kmeans_plusplus
from repro.data import PointStream, make_points
from repro.streaming import ShardBounds, StreamingKMeans, inflate_bounds


def test_stream_parity_with_batch_engine():
    pts, _, _ = make_points(4096, 16, 16, seed=0)
    init = kmeans_plusplus(jax.random.PRNGKey(1), jnp.asarray(pts), 16)
    r_b = engine.fit(jnp.asarray(pts), init, max_iters=50, tol=1e-4,
                     backend="compact")

    stream = PointStream(shard_size=512, data=pts)
    skm = StreamingKMeans(16, seed=1).fit_stream(stream, epochs=6)
    ratio = skm.inertia_of(pts) / float(r_b.inertia)
    assert ratio < 1.05

    # bound carry really engaged: epochs 2+ hit the per-shard cache and
    # the filtered pass did well under dense mini-batch work
    assert skm.stats_.cache_hits >= stream.n_shards
    dense_equiv = skm.stats_.batches * 512 * 16
    assert skm.stats_.distance_evals < 0.8 * dense_equiv


def test_point_stream_determinism_and_coverage():
    ps = PointStream(shard_size=128, n_shards=4, n_dims=8, k=4, seed=3)
    np.testing.assert_array_equal(ps.shard(1), ps.shard(1))
    np.testing.assert_array_equal(ps.shard(5), ps.shard(1))   # wraps
    assert ps.shard(0).shape == (128, 8) and ps.shard(0).dtype == np.float32
    assert not np.array_equal(ps.shard(0), ps.shard(1))

    data = np.arange(100 * 3, dtype=np.float32).reshape(100, 3)
    ds = PointStream(shard_size=32, data=data)
    assert ds.n_shards == 4
    got = np.concatenate([ds.shard(i) for i in range(ds.n_shards)])
    np.testing.assert_array_equal(got, data)   # short last shard kept
    batches = list(ds.batches(epochs=2))
    assert len(batches) == 8
    assert [sid for sid, _ in batches[:4]] == [0, 1, 2, 3]


def test_point_stream_prefetch_protocol():
    ps = PointStream(shard_size=64, n_shards=3, n_dims=4, k=2, seed=0)
    b = ps.global_batch(4)
    assert b["shard_id"] == 1
    np.testing.assert_array_equal(b["points"], ps.shard(1))
    # fit_stream consumes the (step, dict) PrefetchingLoader item shape
    skm = StreamingKMeans(2, init_size=64)
    skm.fit_stream([(s, ps.global_batch(s)) for s in range(3)])
    assert skm.cluster_centers_.shape == (2, 4)
    assert skm.stats_.cache_misses >= 1


def test_not_fitted_before_first_partial_fit():
    skm = StreamingKMeans(8)
    for attr in ("cluster_centers_", "counts_", "labels_"):
        with pytest.raises(NotFittedError):
            getattr(skm, attr)
    with pytest.raises(NotFittedError):
        skm.predict(np.zeros((4, 3), np.float32))
    with pytest.raises(NotFittedError):
        skm.inertia_of(np.zeros((4, 3), np.float32))


def test_cold_start_buffers_then_initializes():
    rng = np.random.default_rng(0)
    skm = StreamingKMeans(4, init_size=100)
    skm.partial_fit(rng.standard_normal((40, 3)).astype(np.float32))
    assert not skm.initialized and skm.stats_.init_batches == 1
    with pytest.raises(NotFittedError):
        skm.cluster_centers_
    skm.partial_fit(rng.standard_normal((70, 3)).astype(np.float32))
    assert skm.initialized
    # buffered batches were replayed through the real step
    assert skm.stats_.batches == 2 and skm.stats_.points_seen == 110
    assert skm.cluster_centers_.shape == (4, 3)
    assert skm.predict(np.zeros((5, 3), np.float32)).shape == (5,)


def test_kmeans_api_partial_fit_delegates():
    pts, _, _ = make_points(1024, 8, 8, seed=2)
    km = KMeans(n_clusters=8, seed=1)
    with pytest.raises(NotFittedError):
        km.labels_
    for sid in range(4):
        km.partial_fit(pts[sid * 256:(sid + 1) * 256], shard_id=sid)
    assert km.cluster_centers_.shape == (8, 8)
    assert km.n_iter_ == 4                     # batches, for the stream path
    assert km.predict(pts[:16]).shape == (16,)
    # a fresh batch fit supersedes the stream state
    km.fit(pts)
    assert km.labels_.shape == (1024,)


def test_decay_bounds_effective_counts():
    stream = PointStream(shard_size=256, n_shards=6, n_dims=4, k=4, seed=1)
    skm = StreamingKMeans(4, decay=0.9, seed=0).fit_stream(stream, epochs=3)
    # decayed horizon: total effective count <= B/(1-decay) + one batch
    assert skm.counts_.sum() <= 256 / (1 - 0.9) + 256
    assert np.isfinite(skm.cluster_centers_).all()
    with pytest.raises(ValueError):
        StreamingKMeans(4, decay=0.0)


def test_reseed_records_drift_and_keeps_bounds_valid():
    stream = PointStream(shard_size=256, n_shards=4, n_dims=4, k=4, seed=5)
    skm = StreamingKMeans(4, seed=0).fit_stream(stream, epochs=2)
    before = skm.stats_.reseeds
    ledger_before = skm._ledger.centroid.copy()
    assert skm._far                       # reservoir populated by batches
    # patience is epoch-scaled: reseed_patience full passes unfed
    skm._since_hit[0] = skm.reseed_patience * len(skm._shards_seen)
    skm._maybe_reseed()
    assert skm.stats_.reseeds == before + 1
    assert skm._ledger.centroid[0] > ledger_before[0]
    # stream continues fine after the reseed (cached bounds still valid:
    # the reseed entered the ledger as drift)
    skm.fit_stream(stream, epochs=1)
    assert np.isfinite(skm.inertia_of(stream.shard(0)))


def test_stream_step_empty_group_drift_is_finite():
    """An empty Yinyang group's segment_max drift is -inf; left
    unclamped it would poison the cumulative drift ledger (inf - inf =
    NaN on the next bound inflation). Regression for the clamp in the
    streaming EMA update strategy (engine.EMA_UPDATE, applied through
    engine.stream_step)."""
    rng = np.random.default_rng(0)
    k, g, b, d = 4, 2, 32, 3
    pts = jnp.asarray(rng.standard_normal((b, d)).astype(np.float32))
    c = jnp.asarray(rng.standard_normal((k, d)).astype(np.float32))
    groups_np = np.zeros((k,), np.int64)            # group 1 is EMPTY
    members, gsize = engine.build_group_tables(groups_np, g)
    core = engine.PassCore(backend="compact", k=k, n_groups=g,
                           cap_n=b, cap_g=g)
    out = engine.stream_step(
        pts, c, jnp.zeros((k,), jnp.float32), jnp.float32(1.0),
        jnp.asarray(groups_np.astype(np.int32)), members, gsize,
        jnp.zeros((b,), jnp.int32), jnp.full((b,), jnp.inf, jnp.float32),
        jnp.zeros((b, g), jnp.float32), jnp.ones((b,), bool),
        core=core)
    assert np.all(np.isfinite(np.asarray(out.gdrift)))
    assert np.all(np.asarray(out.gdrift) >= 0)


# -- property test: bounds survive arbitrary drift -------------------------

def _check_bounds_survive_drift(seed, steps, scale):
    """inflate_bounds must keep ub an upper bound on d(x, c_assign) and
    lb[., g] a lower bound on the group-g min (excluding the assigned
    centroid) after ANY sequence of centroid moves, given only the
    cumulative drift ledgers."""
    rng = np.random.default_rng(seed)
    n, d, k, g = 48, 4, 8, 3
    pts = rng.standard_normal((n, d)).astype(np.float32)
    c = rng.standard_normal((k, d)).astype(np.float32)
    groups = np.arange(k) % g

    d_mat = np.linalg.norm(pts[:, None] - c[None], axis=-1)
    assign = d_mat.argmin(1).astype(np.int32)
    ub = d_mat.min(1).astype(np.float32)
    d_ex = d_mat.copy()
    d_ex[np.arange(n), assign] = np.inf
    lb = np.stack([d_ex[:, groups == j].min(1) for j in range(g)],
                  axis=1).astype(np.float32)

    cum_c = np.zeros(k)
    cum_g = np.zeros(g)
    entry = ShardBounds(assign, ub, lb, cum_c[assign].astype(np.float32),
                        cum_g.copy(), g, float(ub.mean()))
    for _ in range(steps):
        move = rng.standard_normal((k, d)) * scale * rng.uniform(size=(k, 1))
        c = c + move
        dr = np.linalg.norm(move, axis=-1)
        cum_c += dr
        for j in range(g):
            cum_g[j] += dr[groups == j].max()

    ub2, lb2 = inflate_bounds(entry, cum_c, cum_g)
    d_now = np.linalg.norm(pts[:, None] - c[None], axis=-1)
    assert np.all(ub2 >= d_now[np.arange(n), assign] - 1e-3)
    d_now_ex = d_now.copy()
    d_now_ex[np.arange(n), assign] = np.inf
    for j in range(g):
        assert np.all(lb2[:, j] <= d_now_ex[:, groups == j].min(1) + 1e-3)


@pytest.mark.parametrize("seed,steps,scale", [
    (0, 1, 0.05), (1, 3, 0.5), (2, 6, 2.0), (7, 4, 1.0), (11, 2, 0.2),
])
def test_bounds_survive_drift(seed, steps, scale):
    _check_bounds_survive_drift(seed, steps, scale)


try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    pass
else:
    @pytest.mark.slow
    @settings(deadline=None, max_examples=25)
    @given(st.integers(0, 2 ** 16), st.integers(1, 6),
           st.floats(0.01, 2.0))
    def test_bounds_survive_drift_property(seed, steps, scale):
        _check_bounds_survive_drift(seed, steps, scale)
