"""Device-resident engine: parity with Lloyd across every backend.

The engine's contract is the paper's: filters (and their compacted /
block-skipped realisations) change the WORK, never the RESULT. Each
backend must land on Lloyd's fixed point — same assignments, same
inertia — across ragged shapes, single-group (Hamerly) runs, and
iterations where every candidate is filtered out.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (KMeans, NotFittedError, kmeans_plusplus, lloyd,
                        yinyang_compact)
from repro.core import engine
from repro.data import make_points

BACKENDS = ["oracle", "compact", "pallas"]


def _dataset(n, d, k, seed=0):
    pts, _, _ = make_points(n, d, k, seed=seed)
    pts = jnp.asarray(pts)
    init = kmeans_plusplus(jax.random.PRNGKey(seed + 1), pts, k)
    return pts, init


def _assert_parity(r_e, r_l):
    assert int(r_e.n_iters) == int(r_l.n_iters)
    np.testing.assert_array_equal(np.asarray(r_e.assignments),
                                  np.asarray(r_l.assignments))
    np.testing.assert_allclose(float(r_e.inertia), float(r_l.inertia),
                               rtol=1e-5)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("n,d,k,g", [
    (1000, 8, 12, 3),     # N % tile_n != 0, K < tile_k
    (513, 5, 7, 2),       # ragged everything
    (768, 4, 8, 1),       # single group = Hamerly point-level filter
    (2048, 12, 16, 16),   # one group per centroid
])
def test_engine_matches_lloyd(backend, n, d, k, g):
    pts, init = _dataset(n, d, k)
    r_l = lloyd(pts, init, max_iters=50, tol=1e-5)
    r_e = engine.fit(pts, init, n_groups=g, max_iters=50, tol=1e-5,
                     backend=backend, interpret=True, min_cap=64)
    _assert_parity(r_e, r_l)


@pytest.mark.parametrize("backend", BACKENDS)
def test_engine_zero_candidate_iterations(backend):
    # tight, far-apart blobs: after the first assignment the filters
    # eliminate every candidate while centroids still drift (shift>tol)
    pts, _ = _dataset(600, 6, 4, seed=3)
    pts = jnp.asarray(np.asarray(pts) * 0.01)
    centers = jnp.asarray(
        [[0.0] * 6, [100.0] * 6, [-100.0] * 6, [200.0] * 6], jnp.float32)
    pts = pts + centers[jnp.arange(600) % 4]
    init = centers + 0.5
    r_l = lloyd(pts, init, max_iters=20, tol=1e-6)
    r_e, stats = engine.fit(pts, init, n_groups=2, max_iters=20, tol=1e-6,
                            backend=backend, interpret=True, min_cap=64,
                            return_stats=True)
    assert stats.n_iters > 1          # really iterated past the 0-cand step
    _assert_parity(r_e, r_l)


def test_engine_large_path_matches_lloyd():
    # large enough to take the bucketed driver (not the fused small-N
    # path) and to shift capacities at least once
    pts, init = _dataset(6000, 16, 32)
    r_l = lloyd(pts, init, max_iters=50, tol=1e-5)
    r_e, stats = engine.fit(pts, init, n_groups=3, max_iters=50, tol=1e-5,
                            backend="compact", min_cap=256,
                            return_stats=True)
    _assert_parity(r_e, r_l)
    assert len(stats.caps_history) >= 2


def test_engine_no_per_iteration_host_sync():
    """The device-resident claim: host syncs scale with bucket
    transitions (O(log N)), not with iterations."""
    pts, init = _dataset(6000, 16, 32, seed=5)
    r_e, stats = engine.fit(pts, init, n_groups=3, max_iters=50, tol=0.0,
                            backend="compact", return_stats=True)
    assert stats.n_iters > 5
    assert stats.host_syncs < stats.n_iters
    assert stats.host_syncs == len(stats.caps_history) + 1


def test_engine_group_bucket_spill_is_exact():
    """Force a cap_g the data exceeds: the in-pass lax.cond must spill
    to the dense branch, never drop a surviving group."""
    pts, init = _dataset(6000, 8, 24)
    r_l = lloyd(pts, init, max_iters=40, tol=1e-5)
    r_e = engine.fit(pts, init, n_groups=8, max_iters=40, tol=1e-5,
                     backend="compact", max_bucket_switches=1)
    _assert_parity(r_e, r_l)


def test_engine_work_reduction():
    pts, init = _dataset(6000, 16, 32)
    r_l = lloyd(pts, init, max_iters=50, tol=1e-5)
    r_e = engine.fit(pts, init, max_iters=50, tol=1e-5, backend="compact")
    assert float(r_e.distance_evals) < 0.6 * float(r_l.distance_evals)


def test_engine_through_kmeans_api():
    pts, _ = _dataset(1500, 8, 8)
    km_e = KMeans(n_clusters=8, engine="compact", seed=1).fit(pts)
    km_r = KMeans(n_clusters=8, engine=None, seed=1).fit(pts)
    np.testing.assert_array_equal(km_e.labels_, km_r.labels_)
    np.testing.assert_allclose(km_e.inertia_, km_r.inertia_, rtol=1e-5)
    km_h = KMeans(n_clusters=8, algorithm="hamerly", engine="compact",
                  seed=1).fit(pts)
    np.testing.assert_array_equal(km_h.labels_, km_r.labels_)


def test_engine_auto_backend_resolves():
    pts, init = _dataset(512, 4, 4)
    r = engine.fit(pts, init, backend="auto", max_iters=10)
    assert np.isfinite(float(r.inertia))
    with pytest.raises(ValueError):
        engine.fit(pts, init, backend="nope")


def test_engine_auto_routes_tiny_to_lloyd():
    """BENCH_kmeans.json: at uci-small scale the dense Lloyd GEMM beats
    the filtered engine ~3.6x, so 'auto' must route below the n*k
    threshold — and land on the identical fixed point."""
    pts, init = _dataset(512, 8, 16)
    assert 512 * 16 <= engine.AUTO_LLOYD_MAX_WORK
    r, stats = engine.fit(pts, init, backend="auto", max_iters=30,
                          tol=1e-5, return_stats=True)
    assert stats.backend == "lloyd"
    _assert_parity(r, lloyd(pts, init, max_iters=30, tol=1e-5))

    big_pts, big_init = _dataset(4500, 8, 32)
    assert 4500 * 32 > engine.AUTO_LLOYD_MAX_WORK
    _, big_stats = engine.fit(big_pts, big_init, backend="auto",
                              max_iters=10, return_stats=True)
    assert big_stats.backend in ("compact", "pallas")


def test_compact_wrapper_delegates_to_engine_math():
    pts, init = _dataset(4000, 12, 24, seed=7)
    r_l = lloyd(pts, init, max_iters=40, tol=1e-5)
    r_c = yinyang_compact(pts, init, max_iters=40, tol=1e-5)
    np.testing.assert_allclose(float(r_c.inertia), float(r_l.inertia),
                               rtol=1e-5)


def test_not_fitted_error():
    km = KMeans(n_clusters=4)
    for attr in ("cluster_centers_", "labels_", "inertia_", "n_iter_",
                 "distance_evals_"):
        with pytest.raises(NotFittedError):
            getattr(km, attr)
    with pytest.raises(NotFittedError):
        km.predict(jnp.zeros((3, 2)))
    # sklearn convention: still catchable as AttributeError/ValueError
    with pytest.raises(AttributeError):
        km.labels_
    with pytest.raises(ValueError):
        km.predict(jnp.zeros((3, 2)))


# -- tiled assignment (the predict path) -----------------------------------

def test_assign_tiled_matches_dense_argmin():
    """engine.assign: the tiled PassCore pass lands on the dense
    argmin for every (N % tile) raggedness, and returns exact
    distances to the assigned centroid."""
    pts, init = _dataset(3000, 8, 24, seed=2)
    r = engine.fit(pts, init, max_iters=20, backend="compact",
                   tune="off")
    d_ref = np.linalg.norm(np.asarray(pts)[:, None]
                           - np.asarray(r.centroids)[None], axis=-1)
    ref = d_ref.argmin(1)
    for tile in (512, 1024, 4096):        # 3000 is ragged vs all three
        labels, dists = engine.assign(pts, r.centroids, tile_n=tile)
        np.testing.assert_array_equal(np.asarray(labels), ref)
        np.testing.assert_allclose(
            np.asarray(dists), d_ref[np.arange(3000), ref], atol=1e-3)


def test_assign_accepts_prebuilt_tables():
    pts, init = _dataset(700, 5, 10, seed=8)
    groups = engine.group_centroids(init, 3)
    members, gsize = engine.build_group_tables(
        np.asarray(jax.device_get(groups)), 3)
    labels, _ = engine.assign(pts, init, groups=groups, members=members,
                              gsize=gsize, tile_n=256)
    ref = np.linalg.norm(np.asarray(pts)[:, None]
                         - np.asarray(init)[None], axis=-1).argmin(1)
    np.testing.assert_array_equal(np.asarray(labels), ref)


# -- the in-trace bucket machinery (consumed by core.distributed) ----------

def test_cap_ladders_shape_and_budget():
    cap_ns, cap_gs = engine.cap_ladders(819, 6, min_cap=256)
    assert cap_ns[0] == 256 and cap_ns[-1] == 819
    assert cap_gs[0] == 1 and cap_gs[-1] == 6
    assert list(cap_ns) == sorted(cap_ns)
    # the branch budget coarsens interiors but never the top endpoints
    cap_ns, cap_gs = engine.cap_ladders(1 << 16, 64, min_cap=64,
                                        max_branches=8)
    assert len(cap_ns) * len(cap_gs) <= 8
    assert cap_ns[-1] == 1 << 16 and cap_gs[-1] == 64
    # degenerate problems collapse to a single level
    assert engine.cap_ladders(100, 1, min_cap=256) == ((100,), (1,))


def test_select_bucket_hysteresis_and_mandatory_upshift():
    cap_ns, cap_gs = (256, 512, 1024), (1, 4, 8)
    kw = dict(cap_ns=cap_ns, cap_gs=cap_gs, down_n=2, down_g=4)

    def sel(n_cand, gmax, ln, lg):
        ln, lg = engine.select_bucket(
            jnp.int32(n_cand), jnp.int32(gmax), jnp.int32(ln),
            jnp.int32(lg), **kw)
        return int(ln), int(lg)

    assert sel(1000, 6, 0, 0) == (2, 2)       # mandatory upshift
    assert sel(300, 2, 1, 1) == (1, 1)        # inside hysteresis: hold
    assert sel(100, 1, 2, 2) == (0, 0)        # past hysteresis: drop
    assert sel(600, 3, 2, 1) == (2, 1)        # 600*2 > 1024: hold
    # gmax == 0 is "no candidates seen", never downshift evidence
    assert sel(100, 0, 2, 2) == (0, 2)
    # down_n=0 / down_g=0 disable that axis entirely
    ln, lg = engine.select_bucket(
        jnp.int32(100), jnp.int32(1), jnp.int32(2), jnp.int32(2),
        cap_ns=cap_ns, cap_gs=cap_gs, down_n=0, down_g=0)
    assert (int(ln), int(lg)) == (2, 2)


def test_ladder_candidate_pass_matches_fixed_cap():
    """The lax.switch'ed pass at any level equals compact_candidate_pass
    at that level's static caps (same numerics, only dispatch added)."""
    pts, init = _dataset(1024, 8, 24, seed=5)
    k, g = 24, 4
    from repro.core.kmeans import _init_filter_state, group_centroids
    from repro.core.distances import row_norms_sq
    groups = engine.group_centroids(init, g)
    groups_np = np.asarray(jax.device_get(groups))
    members, gsize = engine.build_group_tables(groups_np, g)
    x2 = row_norms_sq(pts)
    c2 = row_norms_sq(init)
    st = _init_filter_state(pts, init, groups, g, x2=x2, c2=c2)
    # 200 survivors: inside even the smallest level's capacity (the
    # cap_n >= count precondition holds at every level under test)
    need = jnp.arange(1024) < 200
    cap_ns, cap_gs = (256, 1024), (2, 4)
    for ln in range(2):
        for lg in range(2):
            ref = engine.compact_candidate_pass(
                pts, init, st.assignments, st.ub, st.lb, groups, members,
                gsize, need, cap_n=cap_ns[ln], cap_g=cap_gs[lg],
                n_groups=g, x2=x2, c2=c2)
            out = engine.ladder_candidate_pass(
                pts, init, st.assignments, st.ub, st.lb, groups, members,
                gsize, need, jnp.int32(ln), jnp.int32(lg),
                cap_ns=cap_ns, cap_gs=cap_gs, n_groups=g, x2=x2, c2=c2)
            for a, b in zip(ref, out):
                np.testing.assert_array_equal(np.asarray(a),
                                              np.asarray(b))
