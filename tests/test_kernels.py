"""Per-kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(Pallas interpret=True executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (build_block_mask, build_group_block_mask,
                           centroid_update, compact_indices,
                           filtered_assign, filtered_assign_auto,
                           grouped_assign, pairwise_sq_dists)
from repro.kernels.ref import (centroid_update_ref, filtered_assign_ref,
                               grouped_assign_ref, pairwise_sq_dists_ref)

SHAPES = [  # (n, d, k) including non-aligned sizes that exercise padding
    (256, 16, 128), (1000, 48, 300), (130, 7, 17), (512, 128, 128),
]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("n,d,k", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_pairwise_sq_dists(n, d, k, dtype):
    kx, kc = jax.random.split(jax.random.PRNGKey(n + k))
    x = jax.random.normal(kx, (n, d), dtype)
    c = jax.random.normal(kc, (k, d), dtype)
    got = pairwise_sq_dists(x, c, interpret=True)
    want = pairwise_sq_dists_ref(x, c)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("n,d,k", SHAPES)
@pytest.mark.parametrize("density", [0.0, 0.35, 1.0])
def test_filtered_assign_block_skip(n, d, k, density):
    tile_n, tile_k = 256, 128
    kx, kc, km = jax.random.split(jax.random.PRNGKey(n * k + 1), 3)
    x = jax.random.normal(kx, (n, d))
    c = jax.random.normal(kc, (k, d))
    gn, gk = -(-n // tile_n), -(-k // tile_k)
    mask = jax.random.bernoulli(km, density, (gn, gk))
    best, idx = filtered_assign(x, c, mask, tile_n=tile_n, tile_k=tile_k,
                                interpret=True)
    bref, iref = filtered_assign_ref(x, c, mask, tile_n, tile_k)
    finite = np.isfinite(np.asarray(bref))
    np.testing.assert_allclose(np.asarray(best)[finite],
                               np.asarray(bref)[finite], rtol=1e-5,
                               atol=1e-5)
    assert (~finite == (np.asarray(idx) == -1)).all()
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(iref))


@pytest.mark.parametrize("n,d,k,g,tile_n,density", [
    (300, 7, 17, 4, 128, 0.5),    # ragged N/K, partial skip
    (512, 16, 64, 8, 256, 1.0),   # aligned, fully dense
    (1000, 12, 40, 5, 256, 0.3),  # mostly skipped
    (130, 3, 6, 6, 64, 0.0),      # everything skipped
])
def test_grouped_assign_matches_ref(n, d, k, g, tile_n, density):
    kx, kc, kg, km = jax.random.split(jax.random.PRNGKey(n + k), 4)
    x = jax.random.normal(kx, (n, d))
    c = jax.random.normal(kc, (k, d))
    groups = np.asarray(jax.random.randint(kg, (k,), 0, g))
    lmax = max(int(np.bincount(groups, minlength=g).max()), 1)
    members = np.full((g, lmax), -1, np.int32)
    for gg in range(g):
        ids = np.nonzero(groups == gg)[0]
        members[gg, :len(ids)] = ids
    ids = jnp.asarray(members)
    c_grouped = c[jnp.maximum(ids, 0)]
    gn = -(-n // tile_n)
    mask = jax.random.bernoulli(km, density, (gn, g))
    got = grouped_assign(x, c_grouped, ids, mask, tile_n=tile_n,
                         interpret=True)
    want = grouped_assign_ref(x, c_grouped, ids, mask, tile_n)
    for name, a, b in zip(("best", "idx", "gmin", "garg", "gmin2"),
                          got, want):
        a, b = np.asarray(a), np.asarray(b)
        if a.dtype.kind == "f":
            finite = np.isfinite(b)
            assert (np.isfinite(a) == finite).all(), name
            np.testing.assert_allclose(a[finite], b[finite], rtol=1e-5,
                                       atol=1e-5, err_msg=name)
        else:
            np.testing.assert_array_equal(a, b, err_msg=name)


def test_group_block_mask_construction():
    need = jnp.zeros((600, 4), bool).at[300:, 1].set(True)
    mask = build_group_block_mask(need, tile_n=256)
    # rows 300.. span tiles 1 and 2 only; they need group 1 only
    expected = np.zeros((3, 4), bool)
    expected[1:, 1] = True
    np.testing.assert_array_equal(np.asarray(mask), expected)


@pytest.mark.parametrize("n,d,k", SHAPES)
def test_centroid_update(n, d, k):
    kx, ka = jax.random.split(jax.random.PRNGKey(n + d))
    x = jax.random.normal(kx, (n, d))
    a = jax.random.randint(ka, (n,), 0, k)
    sums, counts = centroid_update(x, a, k=k, interpret=True)
    sref, cref = centroid_update_ref(x, a, k)
    np.testing.assert_allclose(np.asarray(sums), np.asarray(sref),
                               rtol=1e-5, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(counts), np.asarray(cref))


def test_block_mask_construction():
    n, k, g = 600, 96, 4
    groups = jnp.arange(k) % g
    need = jnp.zeros((n, g), bool).at[:, 1].set(True)
    mask = build_block_mask(need, groups, tile_n=256, tile_k=32)
    # every centroid block containing a group-1 centroid must be live
    assert mask.shape == (3, 3)
    assert bool(mask.any())


def test_fused_auto_path_equals_bruteforce_when_dense():
    kx, kc = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(kx, (500, 24))
    c = jax.random.normal(kc, (64, 24))
    groups = jnp.arange(64) % 4
    need = jnp.ones((500, 4), bool)
    best, idx, density = filtered_assign_auto(x, c, need, groups,
                                              interpret=True)
    want = pairwise_sq_dists_ref(x, c)
    np.testing.assert_array_equal(np.asarray(idx),
                                  np.asarray(jnp.argmin(want, axis=1)))
    assert float(density) == 1.0


def test_compact_indices_matches_nonzero():
    m = jax.random.bernoulli(jax.random.PRNGKey(2), 0.2, (777,))
    idx, valid, count = compact_indices(m, capacity=777)
    ref = np.nonzero(np.asarray(m))[0]
    assert int(count) == len(ref)
    np.testing.assert_array_equal(np.asarray(idx)[:len(ref)], ref)
    assert int(valid.sum()) == len(ref)


@pytest.mark.parametrize("b,h,s,d,bq,bk", [
    (2, 3, 128, 32, 64, 32), (1, 2, 256, 64, 256, 64),
    (1, 1, 64, 16, 16, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(b, h, s, d, bq, bk, dtype):
    from repro.kernels import flash_attention
    from repro.kernels.ref import flash_attention_ref
    key = jax.random.PRNGKey(s + d)
    q, k, v = (jax.random.normal(kk, (b, h, s, d), dtype)
               for kk in jax.random.split(key, 3))
    got = flash_attention(q, k, v, block_q=bq, block_k=bk, interpret=True)
    want = flash_attention_ref(q, k, v)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("g,q,n,p_", [(4, 32, 16, 32), (2, 128, 8, 64),
                                      (1, 16, 128, 16)])
def test_ssd_intra(g, q, n, p_):
    from repro.kernels import ssd_intra
    from repro.kernels.ref import ssd_intra_ref
    key = jax.random.PRNGKey(g + q)
    kc, kb, kx, kd = jax.random.split(key, 4)
    c = jax.random.normal(kc, (g, q, n))
    b = jax.random.normal(kb, (g, q, n))
    x = jax.random.normal(kx, (g, q, p_))
    # realistic negative log-decay accumulation
    cum = jnp.cumsum(-jax.nn.softplus(
        jax.random.normal(kd, (g, q))), axis=1)
    got = ssd_intra(c, b, x, cum, interpret=True)
    want = ssd_intra_ref(c, b, x, cum)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
