"""Autotuner invariants: tuning changes wall-clock, never results.

Covers the ISSUE 3 contract: (1) the disk cache round-trips configs by
problem signature, (2) the search is deterministic under a fixed
measurement function, (3) tuned and default configurations produce
bit-identical assignments/inertia across the engine test matrix,
(4) ``||x||^2`` is computed exactly once per fit (the norm-carry
refactor), (5) the compact pass's gather-vs-GEMM decision follows the
tuned crossover and is exposed in EngineStats.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import tune
from repro.core import EngineConfig, KMeans, kmeans_plusplus, lloyd
from repro.core import engine
from repro.data import make_points


@pytest.fixture
def tmp_cache(tmp_path):
    """Fresh TuneCache in tmp, installed as the process default for the
    duration of the test (fit(tune=...) consults the default)."""
    cache = tune.TuneCache(str(tmp_path / "tune.json"))
    old = tune.set_default_cache(cache)
    assert old is cache
    yield cache
    tune.set_default_cache(None)


def _dataset(n, d, k, seed=0):
    pts, _, _ = make_points(n, d, k, seed=seed)
    pts = jnp.asarray(pts)
    init = kmeans_plusplus(jax.random.PRNGKey(seed + 1), pts, k)
    return pts, init


# -- cache ------------------------------------------------------------------

def test_cache_round_trip(tmp_path):
    path = str(tmp_path / "t.json")
    cache = tune.TuneCache(path)
    sig = tune.signature(3000, 32, 16, platform="cpu")
    cfg = EngineConfig(backend="compact", min_cap=512, down_g=0,
                       refresh_in_pass=True)
    cache.store(sig, cfg, ms=4.2)

    # reload from disk through a NEW instance
    cache2 = tune.TuneCache(path)
    got = cache2.lookup(sig)
    assert got == cfg
    assert cache2.entry(sig)["ms"] == 4.2
    # same pow2 N bucket -> same signature -> hit
    assert tune.signature(2500, 32, 16, platform="cpu") == sig
    # different K, D, N bucket or platform -> miss
    assert cache2.lookup(tune.signature(3000, 64, 16, "cpu")) is None
    assert cache2.lookup(tune.signature(3000, 32, 8, "cpu")) is None
    assert cache2.lookup(tune.signature(9000, 32, 16, "cpu")) is None
    assert cache2.lookup(tune.signature(3000, 32, 16, "tpu")) is None

    cache2.drop(sig)
    assert cache2.lookup(sig) is None
    assert tune.TuneCache(path).lookup(sig) is None   # drop persisted


def test_cache_tolerates_corrupt_file(tmp_path):
    path = str(tmp_path / "t.json")
    with open(path, "w") as fh:
        fh.write("{not json")
    cache = tune.TuneCache(path)
    assert cache.lookup("anything") is None
    cache.store("sig", EngineConfig())          # and can still write
    assert tune.TuneCache(path).lookup("sig") == EngineConfig()


def test_config_dict_round_trip_tolerates_unknown_keys():
    cfg = EngineConfig(backend="compact", chunk=1024)
    d = cfg.to_dict()
    d["knob_from_the_future"] = 7
    assert EngineConfig.from_dict(d) == cfg


def test_env_var_overrides_default_path(tmp_path, monkeypatch):
    monkeypatch.setenv(tune.ENV_VAR, str(tmp_path / "custom.json"))
    assert tune.TuneCache().path == str(tmp_path / "custom.json")


# -- search -----------------------------------------------------------------

def _stub_measure(costs):
    """Deterministic measurement stub: cost surface keyed on knobs."""
    calls = []

    def measure(cfg):
        calls.append(cfg)
        return costs(cfg)
    measure.calls = calls
    return measure


def test_search_is_deterministic_and_finds_stub_optimum(tmp_path):
    def costs(cfg):
        if cfg.backend == "lloyd":
            return 5.0
        # optimum: compact, min_cap=512, down_g=0, refresh_in_pass=True
        return (3.0 + abs(cfg.min_cap - 512) / 1000.0
                + (0.5 if cfg.down_g else 0.0)
                + (0.0 if cfg.refresh_in_pass else 0.25))

    pts, init = _dataset(3000, 16, 32)
    cache = tune.TuneCache(str(tmp_path / "a.json"))
    m1 = _stub_measure(costs)
    best1 = tune.autotune(pts, init, cache=cache, measure=m1)
    assert best1.backend == "compact"
    assert best1.min_cap == 512
    assert best1.down_g == 0
    assert best1.refresh_in_pass is True

    m2 = _stub_measure(costs)
    best2 = tune.autotune(pts, init,
                          cache=tune.TuneCache(str(tmp_path / "b.json")),
                          measure=m2)
    assert best1 == best2
    assert [c.to_dict() for c in m1.calls] == \
        [c.to_dict() for c in m2.calls]

    # the search persisted its winner under the problem's signature
    sig = tune.signature(3000, 32, 16)
    assert cache.lookup(sig) == best1
    assert cache.entry(sig)["lloyd_ms"] == pytest.approx(5000.0)


def test_search_backend_grid_can_pick_lloyd(tmp_path):
    pts, init = _dataset(1000, 8, 8)
    best = tune.autotune(
        pts, init, cache=tune.TuneCache(str(tmp_path / "c.json")),
        measure=_stub_measure(
            lambda cfg: 1.0 if cfg.backend == "lloyd" else 9.0))
    assert best.backend == "lloyd"


def test_get_or_tune_prefers_cache_hit(tmp_path):
    pts, init = _dataset(1000, 8, 8)
    cache = tune.TuneCache(str(tmp_path / "d.json"))
    pinned = EngineConfig(backend="compact", chunk=4096)
    cache.store(tune.signature(1000, 8, 8), pinned)
    m = _stub_measure(lambda cfg: 1.0)
    got = tune.get_or_tune(pts, init, cache=cache, measure=m)
    assert got == pinned
    assert m.calls == []                       # no measurement happened


# -- fit integration: tuning never changes results --------------------------

TUNED_VARIANTS = [
    EngineConfig(backend="compact", min_cap=128, chunk=1024,
                 group_gather_factor=2, down_n=4, down_g=2),
    EngineConfig(backend="compact", min_cap=512, down_n=0, down_g=0,
                 refresh_in_pass=True),
]


@pytest.mark.parametrize("n,d,k,g", [
    (1000, 8, 12, 3),     # N % tile_n != 0, K < tile_k
    (513, 5, 7, 2),       # ragged everything
    (768, 4, 8, 1),       # single group = Hamerly point-level filter
    (2048, 12, 16, 16),   # one group per centroid
])
def test_tuned_configs_bit_identical_on_engine_matrix(n, d, k, g):
    pts, init = _dataset(n, d, k)
    base = engine.fit(pts, init, n_groups=g, max_iters=50, tol=1e-5,
                      backend="compact", min_cap=64, tune="off")
    r_l = lloyd(pts, init, max_iters=50, tol=1e-5)
    for cfg in TUNED_VARIANTS:
        r = engine.fit(pts, init, n_groups=g, max_iters=50, tol=1e-5,
                       config=cfg, tune="off")
        np.testing.assert_array_equal(np.asarray(r.assignments),
                                      np.asarray(base.assignments))
        assert float(r.inertia) == float(base.inertia)
        assert int(r.n_iters) == int(base.n_iters)
        # and both sit on Lloyd's fixed point
        np.testing.assert_array_equal(np.asarray(r.assignments),
                                      np.asarray(r_l.assignments))


def test_fit_tune_auto_consults_default_cache(tmp_cache):
    pts, init = _dataset(4200, 8, 48)          # big enough to skip lloyd
    marker = EngineConfig(backend="compact", min_cap=128, down_n=0,
                          down_g=0)
    tmp_cache.store(tune.signature(4200, 48, 8), marker)
    r_t, st = engine.fit(pts, init, max_iters=30, tune="auto",
                         return_stats=True)
    assert st.config == marker.to_dict()
    r_off = engine.fit(pts, init, max_iters=30, tune="off")
    np.testing.assert_array_equal(np.asarray(r_t.assignments),
                                  np.asarray(r_off.assignments))
    assert float(r_t.inertia) == float(r_off.inertia)


def test_fit_tune_force_uses_cache_hit_without_search(tmp_cache):
    pts, init = _dataset(900, 6, 9)
    pinned = EngineConfig(backend="lloyd")
    tmp_cache.store(tune.signature(900, 9, 6), pinned)
    r, st = engine.fit(pts, init, max_iters=20, tune="force",
                       return_stats=True)
    assert st.backend == "lloyd"               # the pinned choice ran
    r_ref = lloyd(pts, init, max_iters=20)
    np.testing.assert_array_equal(np.asarray(r.assignments),
                                  np.asarray(r_ref.assignments))


def test_explicit_kwargs_override_tuned_config(tmp_cache):
    pts, init = _dataset(4200, 8, 48)
    tmp_cache.store(tune.signature(4200, 48, 8),
                    EngineConfig(backend="compact", min_cap=1024))
    _, st = engine.fit(pts, init, max_iters=10, tune="auto", min_cap=64,
                       backend="compact", return_stats=True)
    assert st.config["min_cap"] == 64


def test_kmeans_api_tune_validation_and_passthrough(tmp_cache):
    with pytest.raises(ValueError):
        KMeans(n_clusters=4, tune="sometimes")
    pts, _ = _dataset(1500, 8, 8)
    km = KMeans(n_clusters=8, engine="compact", seed=1, tune="off").fit(pts)
    km2 = KMeans(n_clusters=8, engine="compact", seed=1,
                 tune="auto").fit(pts)
    np.testing.assert_array_equal(km.labels_, km2.labels_)


def test_streaming_adopts_tuned_config(tmp_cache):
    b, d, k = 512, 16, 16
    tmp_cache.store(
        tune.signature(b, k, d),
        EngineConfig(backend="compact", min_cap=128, chunk=4096,
                     group_gather_factor=8))
    from repro.streaming import StreamingKMeans
    sk = StreamingKMeans(k, seed=0, tune="auto")
    sk_off = StreamingKMeans(k, seed=0, tune="off")
    for i in range(4):
        batch = np.asarray(make_points(b, d, k, seed=i)[0])
        sk.partial_fit(batch, shard_id=i)
        sk_off.partial_fit(batch, shard_id=i)
    assert sk.min_bucket == 128 and sk.chunk == 4096 and sk._ggf == 8
    assert sk_off.min_bucket == 256 and sk_off.chunk == 2048
    # tuning never changes the stream state
    np.testing.assert_allclose(sk.cluster_centers_,
                               sk_off.cluster_centers_)

    # explicitly passed knobs keep precedence over the tuned entry
    # (only the non-conflicting crossover factor is adopted)
    sk_exp = StreamingKMeans(k, seed=0, tune="auto", min_bucket=512,
                             chunk=1024)
    sk_exp.partial_fit(np.asarray(make_points(b, d, k, seed=0)[0]),
                       shard_id=0)
    assert sk_exp.min_bucket == 512 and sk_exp.chunk == 1024
    assert sk_exp._ggf == 8


# -- norm-carry contract ----------------------------------------------------

def test_x2_computed_exactly_once_per_fit(tmp_cache, monkeypatch):
    """The ISSUE 3 norm-carry contract: ||x||^2 over the full point set
    is evaluated exactly once per fit (at _init_carry), then carried
    through the while_loop — no per-iteration recomputation anywhere
    in the engine's traces."""
    n, d, k = 5003, 11, 40                      # fresh shape => fresh trace
    pts, init = _dataset(n, d, k)
    real = engine.row_norms_sq
    full_n_calls = []

    def counting(x):
        if x.ndim == 1 or x.shape[0] == n:
            full_n_calls.append(x.shape)
        return real(x)

    monkeypatch.setattr(engine, "row_norms_sq", counting)
    r, st = engine.fit(pts, init, max_iters=30, tol=1e-5,
                       backend="compact", tune="off", return_stats=True)
    full_point_norms = [s for s in full_n_calls if s == (n, d)]
    assert len(full_point_norms) == 1, full_n_calls
    assert st.n_iters > 2                       # it really iterated
    assert st.x2_evals == 1


# -- the tuned gather-vs-GEMM crossover -------------------------------------

def test_use_groups_decision_follows_tuned_crossover(tmp_cache):
    # k=24 in g=8 groups: l_max ~ 3, so a cap_g=4 bucket gives
    # 4*3*factor vs k=24 -> factor 2 qualifies, factor 8 does not
    assert engine.use_groups_decision(cap_n=512, cap_g=4, l_max=3, k=24,
                                      chunk=2048, group_gather_factor=2)
    assert not engine.use_groups_decision(cap_n=512, cap_g=4, l_max=3,
                                          k=24, chunk=2048,
                                          group_gather_factor=8)
    # and the cap_n <= chunk guard still applies
    assert not engine.use_groups_decision(cap_n=4096, cap_g=4, l_max=3,
                                          k=24, chunk=2048,
                                          group_gather_factor=2)

    pts, init = _dataset(6000, 8, 24)
    results = {}
    for ggf in (2, 8):
        cfg = EngineConfig(backend="compact", group_gather_factor=ggf)
        r, st = engine.fit(pts, init, n_groups=8, max_iters=40, tol=1e-5,
                           config=cfg, tune="off", return_stats=True)
        assert len(st.use_groups) == len(st.caps_history)
        results[ggf] = (r, st)
    # the big factor must never take the gather path; the small one
    # must have taken it at least once on this shape
    assert not any(results[8][1].use_groups)
    assert any(results[2][1].use_groups)
    # ...and the decision changed only the path, not the answer
    np.testing.assert_array_equal(
        np.asarray(results[2][0].assignments),
        np.asarray(results[8][0].assignments))
    assert float(results[2][0].inertia) == float(results[8][0].inertia)


# -- shard-count signature dimension (the distributed engine's key) --------

def test_signature_shard_dimension(tmp_cache):
    # shards=1 keeps the original key format: existing caches stay valid
    base = tune.signature(3000, 64, 32, "cpu")
    assert base == tune.signature(3000, 64, 32, "cpu", shards=1)
    assert "|s" not in base
    s8 = tune.signature(3000, 64, 32, "cpu", shards=8)
    assert s8 == base + "|s8"
    # per-shard N buckets independently of the shard count
    assert tune.signature(819, 64, 32, "cpu", shards=4) == \
        "cpu|n1024|k64|d32|s4"

    # sharded winners resolve only under their own key
    cfg = EngineConfig(min_cap=64, chunk=1024)
    tmp_cache.store(tune.signature(819, 64, 32, shards=4), cfg, ms=1.0)
    assert tune.lookup(n=819, k=64, d=32, shards=4) == cfg
    assert tune.lookup(n=819, k=64, d=32) is None
    assert tune.lookup(n=819, k=64, d=32, shards=8) is None


def test_autotune_stores_under_shard_signature(tmp_cache):
    pts, init = _dataset(512, 8, 16)
    calls = []

    def measure(cfg):
        calls.append(cfg)
        return 1.0 if cfg.backend == "lloyd" else 0.5

    best = tune.autotune(pts, init, cache=tmp_cache, measure=measure,
                         max_rounds=0, shards=4)
    sig = tune.signature(512, 16, 8, shards=4)
    assert sig.endswith("|s4")
    assert tmp_cache.lookup(sig) == best
    # the single-device key is untouched
    assert tmp_cache.lookup(tune.signature(512, 16, 8)) is None
