"""Roofline machinery: HLO collective parser + accounting sanity."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.roofline.analysis import (_shape_bytes, collective_bytes_per_device,
                                     model_flops, roofline)


def test_shape_bytes_parser():
    assert _shape_bytes("f32[128,64]{1,0}") == 128 * 64 * 4
    assert _shape_bytes("bf16[2,3,4]") == 24 * 2
    assert _shape_bytes("(f32[8], s32[8])") == 8 * 4 + 8 * 4
    assert _shape_bytes("pred[16]") == 16
    assert _shape_bytes("f32[]") == 4


def test_collective_parser_counts_psum():
    hlo = """
  %x = f32[1024,512]{1,0} all-reduce(f32[1024,512]{1,0} %p), replica_groups={}
  %y = bf16[64]{0} all-gather(bf16[32]{0} %q), dimensions={0}
  %z = (f32[16], u32[]) all-reduce-start(f32[16] %r)
  %w = f32[16] all-reduce-done((f32[16], u32[]) %z)
"""
    out = collective_bytes_per_device(hlo)
    assert out["bytes"]["all-reduce"] == 1024 * 512 * 4 + 16 * 4 + 4
    assert out["bytes"]["all-gather"] == 64 * 2
    assert out["counts"]["all-reduce"] == 2  # start counted, done skipped
    assert out["total"] > 0


def test_collective_parser_on_real_lowering():
    mesh = jax.make_mesh((1,), ("d",))
    from jax.sharding import NamedSharding, PartitionSpec as P

    def f(x):
        return x.sum()

    with mesh:
        lowered = jax.jit(
            f, in_shardings=NamedSharding(mesh, P("d")),
            out_shardings=NamedSharding(mesh, P())).lower(
                jax.ShapeDtypeStruct((64, 64), jnp.float32))
        txt = lowered.compile().as_text()
    out = collective_bytes_per_device(txt)   # 1 device: may be zero; parses
    assert out["total"] >= 0


def test_model_flops_moe_uses_active_params():
    from repro.configs import get_config
    dense = get_config("mistral-nemo-12b")
    moe = get_config("qwen3-moe-235b-a22b")
    f_moe = model_flops(moe, "train", 1, 1)
    # active fraction: top-8 of 128 experts -> expert flops scaled by 1/16
    total_expert_params = (moe.n_experts * moe.d_model * moe.d_ff * 3
                           * moe.n_layers)
    active_expert_params = total_expert_params * moe.moe_top_k / moe.n_experts
    assert f_moe < 6 * (total_expert_params + 1e12)
    assert f_moe > 6 * active_expert_params  # attn etc on top


def test_roofline_identifies_bottleneck():
    r = roofline({"flops": 1e12, "bytes accessed": 1e9}, 0, 256)
    assert r["bottleneck"] == "compute"
    r = roofline({"flops": 1e9, "bytes accessed": 1e12}, 0, 256)
    assert r["bottleneck"] == "memory"
    r = roofline({"flops": 1e9, "bytes accessed": 1e9}, int(1e12), 256)
    assert r["bottleneck"] == "collective"
