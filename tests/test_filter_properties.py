"""Hypothesis property tests for the system's invariants.

The safety property of the whole paper: the triangle-inequality bounds
are SOUND at every iteration (ub is a true upper bound on the assigned
distance, lb a true lower bound per group), and therefore filtering is
exact — filtered assignments always equal Lloyd's on arbitrary inputs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

# CI runs the hypothesis sweeps in their own lane (-m slow); the quick
# tier-1 lane deselects them with -m "not slow"
pytestmark = pytest.mark.slow

from repro.core import lloyd, yinyang
from repro.core.distances import pairwise_dists
from repro.core.kmeans import (_filtered_step, _init_filter_state,
                               group_centroids)


def _random_problem(seed, n, d, k):
    key = jax.random.PRNGKey(seed)
    kp, kc = jax.random.split(key)
    pts = jax.random.normal(kp, (n, d)) * 3.0
    init = pts[jax.random.choice(kc, n, (k,), replace=False)]
    return pts, init


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000),
       n=st.integers(20, 300),
       d=st.integers(1, 24),
       k=st.integers(2, 12),
       g=st.integers(1, 6))
def test_filtered_equals_lloyd_on_arbitrary_data(seed, n, d, k, g):
    k = min(k, n // 2)
    g = min(g, k)
    pts, init = _random_problem(seed, n, d, k)
    r_l = lloyd(pts, init, max_iters=25, tol=1e-6)
    r_f = yinyang(pts, init, n_groups=g, max_iters=25, tol=1e-6)
    a_l = np.asarray(r_l.assignments)
    a_f = np.asarray(r_f.assignments)
    if (a_l == a_f).all():
        return
    # Exactness modulo fp ties: divergent trajectories are only legal
    # via near-ties; both must reach (numerically) equal-quality fixed
    # points, and the filtered assignment must be optimal w.r.t. its
    # own centroids (ties cannot make it pick a WORSE centroid).
    np.testing.assert_allclose(float(r_l.inertia), float(r_f.inertia),
                               rtol=1e-4)
    pts64 = np.asarray(pts, np.float64)
    c64 = np.asarray(r_f.centroids, np.float64)
    d_f = np.sqrt(((pts64[:, None, :] - c64[None]) ** 2).sum(-1))
    rows = np.arange(len(a_f))
    assert (d_f[rows, a_f] <= d_f.min(axis=1) + 1e-4).all()


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000),
       n=st.integers(30, 200),
       d=st.integers(2, 16),
       k=st.integers(4, 10),
       iters=st.integers(1, 6))
def test_bounds_remain_sound_across_iterations(seed, n, d, k, iters):
    """After any number of filtered steps: ub >= d(x, a(x)) and
    lb[x, g] <= min_{c in g, c != a(x)} d(x, c)."""
    g = max(k // 3, 1)
    pts, init = _random_problem(seed, n, d, k)
    groups = group_centroids(init.astype(jnp.float32), g)
    state = _init_filter_state(pts, init.astype(jnp.float32), groups, g)
    for _ in range(iters):
        state = _filtered_step(pts, state, groups, g, k)

    # float64 diff-form oracle: the expanded-form fp32 distance has
    # cancellation error ~1e-3 at small distances (false violations)
    pts64 = np.asarray(pts, np.float64)
    c64 = np.asarray(state.centroids, np.float64)
    d_all = np.sqrt(((pts64[:, None, :] - c64[None]) ** 2).sum(-1))
    a = np.asarray(state.assignments)
    ub = np.asarray(state.ub)
    lb = np.asarray(state.lb)
    rows = np.arange(n)
    # ub soundness
    assert (ub + 1e-3 >= d_all[rows, a]).all()  # 1e-3: fp32 headroom
    # lb soundness per group (excluding the assigned centroid)
    gid = np.asarray(groups)
    for gg in range(g):
        cols = np.nonzero(gid == gg)[0]
        if len(cols) == 0:
            continue
        dg = d_all[:, cols].copy()
        for i in rows:
            if gid[a[i]] == gg:
                dg[i, list(cols).index(a[i])] = np.inf
        true_min = dg.min(axis=1)
        assert (lb[:, gg] <= true_min + 1e-3).all()


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(10, 400),
       frac=st.floats(0.0, 1.0))
def test_compaction_preserves_set(seed, n, frac):
    from repro.kernels import compact_indices
    key = jax.random.PRNGKey(seed)
    mask = jax.random.bernoulli(key, frac, (n,))
    idx, valid, count = compact_indices(mask, capacity=n)
    ref = set(np.nonzero(np.asarray(mask))[0].tolist())
    got = set(np.asarray(idx)[:int(count)].tolist())
    assert got == ref


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_quantized_psum_error_feedback_converges(seed):
    """Error feedback: repeated compress->feedback cycles of the same
    tensor keep the CUMULATIVE error bounded (no drift)."""
    from repro.optim.compression import quantize_int8, dequantize_int8
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (64,)) * 10
    residual = jnp.zeros_like(x)
    total_in, total_out = jnp.zeros_like(x), jnp.zeros_like(x)
    for _ in range(20):
        target = x + residual
        q, s = quantize_int8(target)
        deq = dequantize_int8(q, s)
        residual = target - deq
        total_in = total_in + x
        total_out = total_out + deq
    # cumulative transmitted value tracks cumulative true value within
    # one quantisation step (error feedback property)
    err = np.abs(np.asarray(total_out - total_in)).max()
    step = float(jnp.max(jnp.abs(x + residual)) / 127.0)
    assert err <= 2 * step + 1e-5
