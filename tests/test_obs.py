"""Observability layer: telemetry must be free and truthful.

The obs contract has two halves, both tested here:

* **Free** — turning the ring/metrics on changes NOTHING about the
  results: bit-identical assignments/inertia on every backend, and the
  zero-host-sync execution contract (``EngineStats.host_syncs``) is
  unchanged, because the ring rides the device loop carry and is
  drained exactly once at exit.
* **Truthful** — the ring's evals column reconciles EXACTLY with the
  engine's compensated ``EvalCount`` total (``init_evals +
  ring[:, COL_EVALS].sum() == distance_evals``, no tolerance), the
  epilogue row carries the true local inertia, and the shard-ring
  reductions (sum for additive counters, max for high-waters) are the
  arithmetic they claim.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, kmeans_plusplus
from repro.core.api import KMeans
from repro.data import make_points
from repro.obs import (MetricsRegistry, ObsConfig, add_ring_listener,
                       caps_from_ring, normalize_obs, provenance,
                       reduce_shard_rings, remove_ring_listener,
                       shard_skew, span, summarize_ring)
from repro.obs.ring import (COL_EVALS, COL_INERTIA, COL_N_CAND,
                            N_COUNTERS, RING_COLUMNS)
from repro.runtime.fault_tolerance import StragglerWatchdog

BACKENDS = ["oracle", "compact", "pallas"]


def _dataset(n=1500, d=8, k=12, seed=0):
    pts, _, _ = make_points(n, d, k, seed=seed)
    pts = jnp.asarray(pts)
    init = kmeans_plusplus(jax.random.PRNGKey(seed + 1), pts, k)
    return pts, init


# -------------------------------------------------------------------------
# free: obs on == obs off, bit for bit, same host-sync count
# -------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_obs_bit_identical_and_host_syncs_unchanged(backend):
    pts, init = _dataset()
    kw = dict(n_groups=3, max_iters=40, tol=1e-5, backend=backend,
              interpret=True, tune="off", return_stats=True)
    r_off, s_off = engine.fit(pts, init, **kw)
    r_on, s_on = engine.fit(pts, init, obs=ObsConfig(
        registry=MetricsRegistry()), **kw)
    np.testing.assert_array_equal(np.asarray(r_off.assignments),
                                  np.asarray(r_on.assignments))
    np.testing.assert_array_equal(np.asarray(r_off.centroids),
                                  np.asarray(r_on.centroids))
    assert float(r_off.inertia) == float(r_on.inertia)
    assert int(r_off.n_iters) == int(r_on.n_iters)
    # the execution contract is untouched: same number of host syncs
    assert s_on.host_syncs == s_off.host_syncs
    assert s_off.ring is None and s_on.ring is not None


# -------------------------------------------------------------------------
# truthful: the ring reconciles exactly with the engine's counters
# -------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_ring_evals_sum_matches_evalcount_exactly(backend):
    pts, init = _dataset(n=2000, d=10, k=16)
    res, stats = engine.fit(pts, init, n_groups=4, max_iters=30,
                            tol=1e-6, backend=backend, interpret=True,
                            tune="off", return_stats=True,
                            obs=ObsConfig(registry=MetricsRegistry()))
    ring = stats.ring
    assert ring.shape == (int(res.n_iters) + 1, N_COUNTERS)
    total = stats.init_evals + float(np.sum(ring[:, COL_EVALS]))
    assert total == float(res.distance_evals)          # EXACT, no rtol
    # the epilogue row carries the converged inertia
    np.testing.assert_allclose(ring[-1, COL_INERTIA],
                               float(res.inertia), rtol=1e-5)


def test_ladder_obs_parity_and_caps_column():
    """The in-trace capacity ladder (down_n/down_g levels switched by
    ``lax.switch``) must stay bit-identical under obs, and the ring's
    cap columns must replay the caps_history the driver reports."""
    pts, init = _dataset(n=3000, d=8, k=24, seed=2)
    cfg = engine.EngineConfig(backend="compact", down_n=2, down_g=2,
                              min_cap=128)
    kw = dict(n_groups=4, max_iters=40, tol=1e-5, config=cfg,
              tune="off", return_stats=True)
    r_off, _ = engine.fit(pts, init, **kw)
    r_on, s_on = engine.fit(pts, init, obs=ObsConfig(
        registry=MetricsRegistry()), **kw)
    np.testing.assert_array_equal(np.asarray(r_off.assignments),
                                  np.asarray(r_on.assignments))
    assert float(r_off.inertia) == float(r_on.inertia)
    assert caps_from_ring(s_on.ring) == s_on.caps_history


def test_engine_stats_to_dict_json_serializable():
    pts, init = _dataset()
    _, stats = engine.fit(pts, init, n_groups=3, max_iters=20,
                          tol=1e-5, backend="compact", tune="off",
                          return_stats=True,
                          obs=ObsConfig(registry=MetricsRegistry()))
    d = stats.to_dict()
    json.dumps(d)                       # must not raise
    assert d["ring_columns"] == list(RING_COLUMNS)
    assert d["telemetry"]["iters"] == int(stats.n_iters)
    assert 0.0 < d["telemetry"]["mean_candidate_fraction"] <= 1.0


def test_kmeans_api_obs_and_stats():
    pts, _ = _dataset()
    reg = MetricsRegistry()
    km = KMeans(12, engine="compact", max_iters=25, tune="off", obs=reg)
    km.fit(pts)
    assert km.stats_ is not None and km.stats_.ring is not None
    assert km.stats_.telemetry()["iters"] == km.n_iter_
    km_plain = KMeans(12, engine="compact", max_iters=25, tune="off")
    km_plain.fit(pts)
    np.testing.assert_array_equal(np.asarray(km.labels_),
                                  np.asarray(km_plain.labels_))
    assert [e for e in reg.events if e["event"] == "engine_fit"]


# -------------------------------------------------------------------------
# live drain
# -------------------------------------------------------------------------

def test_live_drain_emits_every_iteration():
    pts, init = _dataset(n=800, d=6, k=8)
    rows = []
    cb = lambda it, row: rows.append((int(it), row))  # noqa: E731
    add_ring_listener(cb)
    try:
        res, _ = engine.fit(
            pts, init, n_groups=2, max_iters=20, tol=1e-6,
            backend="compact", tune="off", return_stats=True,
            obs=ObsConfig(live_drain=True,
                          registry=MetricsRegistry()))
        jax.effects_barrier()
    finally:
        remove_ring_listener(cb)
    # one row per iteration + the epilogue row
    assert len(rows) == int(res.n_iters) + 1
    assert all(len(r) == N_COUNTERS for _, r in rows)


# -------------------------------------------------------------------------
# shard-ring reductions + the straggler watchdog
# -------------------------------------------------------------------------

def test_reduce_shard_rings_and_skew_arithmetic():
    # synthetic 2-shard ring: shard 1 does 3x the evals of shard 0
    s0 = np.zeros((3, N_COUNTERS), np.float32)
    s1 = np.zeros((3, N_COUNTERS), np.float32)
    s0[:, COL_EVALS] = [10.0, 20.0, 30.0]
    s1[:, COL_EVALS] = [30.0, 60.0, 90.0]
    s0[:, COL_N_CAND] = [5, 4, 3]
    s1[:, COL_N_CAND] = [1, 1, 1]
    s0[:, 1] = [1.0, 2.0, 3.0]          # gmax: reduced by max
    s1[:, 1] = [4.0, 1.0, 1.0]
    rings = np.stack([s0, s1])
    g = reduce_shard_rings(rings)
    np.testing.assert_allclose(g[:, COL_EVALS], [40.0, 80.0, 120.0])
    np.testing.assert_allclose(g[:, COL_N_CAND], [6, 5, 4])
    np.testing.assert_allclose(g[:, 1], [4.0, 2.0, 3.0])
    skew = shard_skew(rings)
    np.testing.assert_allclose(skew, [1.5, 1.5, 1.5])   # max/mean


def test_straggler_watchdog_flags_slow_shard():
    events = []
    wd = StragglerWatchdog(threshold=2.0,
                           on_straggler=events.append)
    # balanced step: nothing flagged, median seeds the EWMA
    assert wd.observe_shards(0, [1.0, 1.1, 0.9, 1.0]) == []
    assert wd.ewma == pytest.approx(1.0)
    # shard 2 does 5x the median work: flagged, EWMA tracks median
    flagged = wd.observe_shards(1, [1.0, 1.0, 5.0, 1.0])
    assert flagged == [2]
    assert events and events[0]["shard"] == 2
    assert events[0]["step"] == 1 and events[0]["median"] == 1.0
    # the outlier didn't poison the EWMA
    assert wd.ewma == pytest.approx(1.0)


def test_distributed_stats_on_single_device_mesh():
    """Tier-1 (1-device) coverage of the distributed stats path: ring
    populated, skew degenerate at 1.0, evals invariant global, stats
    serializable, watchdog fed one observation per iteration."""
    from repro.core.distributed import distributed_yinyang
    pts, init = _dataset(n=1024, d=8, k=12, seed=4)
    mesh = jax.make_mesh((1,), ("data",))
    wd = StragglerWatchdog()
    res, stats = distributed_yinyang(
        pts, init, mesh, n_groups=3, max_iters=25, tol=1e-5,
        backend="compact", return_stats=True,
        obs=MetricsRegistry(), watchdog=wd)
    assert stats.ring is not None
    assert stats.shard_rings.shape[0] == 1
    np.testing.assert_allclose(stats.shard_skew, 1.0)
    total = stats.init_evals + float(np.sum(stats.ring[:, COL_EVALS]))
    assert total == float(res.distance_evals)
    json.dumps(stats.to_dict())
    assert wd.ewma is not None and wd.events == []


# -------------------------------------------------------------------------
# registry / exporters / spans / config coercion
# -------------------------------------------------------------------------

def test_registry_metrics_and_prometheus_text(tmp_path):
    reg = MetricsRegistry()
    reg.counter("fits_total", "fits", labels={"backend": "compact"}).inc(3)
    reg.gauge("last_iters", "iters").set(7.0)
    h = reg.histogram("lat_s", "latency", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(0.5)
    text = reg.to_prometheus()
    assert "# TYPE fits_total counter" in text
    assert 'fits_total{backend="compact"} 3' in text
    assert "last_iters 7" in text
    assert 'lat_s_bucket{le="0.1"} 1' in text
    assert 'lat_s_bucket{le="+Inf"} 2' in text
    assert "lat_s_count 2" in text
    # get-or-create: same (name, labels) returns the same instrument
    assert reg.counter("fits_total",
                       labels={"backend": "compact"}).value == 3
    p = reg.export_prometheus(tmp_path / "m.prom")
    assert (tmp_path / "m.prom").read_text() == text and p


def test_registry_jsonl_export_and_span(tmp_path):
    reg = MetricsRegistry()
    with span("unit.region", registry=reg, tag="x") as s:
        s["result"] = 42
    reg.log_event("custom", foo="bar")
    path = reg.export_jsonl(tmp_path / "ev.jsonl")
    lines = [json.loads(l) for l in open(path)]
    assert [e["event"] for e in lines] == ["span", "custom"]
    ev = lines[0]
    assert ev["name"] == "unit.region" and ev["tag"] == "x"
    assert ev["result"] == 42 and ev["seconds"] >= 0.0
    # span duration also landed in the labelled histogram
    hist = reg.histogram("span_seconds",
                         labels={"span": "unit.region"})
    assert hist.count == 1


def test_normalize_obs_coercions():
    assert normalize_obs(None) is None
    assert normalize_obs(False) is None
    cfg = normalize_obs(True)
    assert isinstance(cfg, ObsConfig) and cfg.ring
    reg = MetricsRegistry()
    cfg2 = normalize_obs(reg)
    assert cfg2.resolve_registry() is reg
    assert normalize_obs(cfg2) is cfg2


def test_provenance_shape():
    p = provenance()
    for key in ("timestamp", "git_sha", "jax_version", "platform",
                "device_count"):
        assert key in p
    json.dumps(p)


# -------------------------------------------------------------------------
# streaming driver publishes
# -------------------------------------------------------------------------

def test_streaming_obs_metrics_and_parity():
    from repro.streaming import StreamingKMeans
    pts_np, _, _ = make_points(2400, 8, 10, seed=5)
    reg = MetricsRegistry()
    sk_on = StreamingKMeans(10, n_groups=2, seed=0, tune="off", obs=reg)
    sk_off = StreamingKMeans(10, n_groups=2, seed=0, tune="off")
    for epoch in range(2):
        for i in range(4):
            batch = pts_np[i * 600:(i + 1) * 600]
            sk_on.partial_fit(batch, shard_id=i)
            sk_off.partial_fit(batch, shard_id=i)
    np.testing.assert_array_equal(np.asarray(sk_on.cluster_centers_),
                                  np.asarray(sk_off.cluster_centers_))
    evts = [e for e in reg.events if e["event"] == "stream_batch"]
    assert len(evts) == sk_on.stats_.batches
    assert reg.counter("stream_points_total").value == \
        sk_on.stats_.points_seen
    # epoch 2 re-presents the shards: the bound cache must report hits
    assert any(e["cache_hit"] for e in evts)
    assert sk_on.stats_.to_dict()["cache_hits"] > 0
