"""Checkpoint roundtrip, atomicity, async save, elastic restore."""
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint


def _state(seed=0):
    key = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(key, (8, 16)),
                       "b": jnp.zeros((16,))},
            "step": jnp.int32(7)}


def test_roundtrip(tmp_path):
    state = _state()
    save_checkpoint(tmp_path, 7, state)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        state)
    restored, step = restore_checkpoint(tmp_path, like)
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_pointer_tracks_newest(tmp_path):
    save_checkpoint(tmp_path, 1, _state(1))
    save_checkpoint(tmp_path, 5, _state(2))
    assert latest_step(tmp_path) == 5
    restored, step = restore_checkpoint(
        tmp_path, jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), _state()))
    assert step == 5


def test_async_save_completes(tmp_path):
    t = save_checkpoint(tmp_path, 3, _state(), async_=True)
    t.join()
    assert latest_step(tmp_path) == 3


def test_corrupt_tmp_dir_never_published(tmp_path):
    save_checkpoint(tmp_path, 2, _state())
    # leftover tmp dirs (simulating a crash mid-save) are invisible
    (tmp_path / ".tmp_step_000009_123").mkdir()
    assert latest_step(tmp_path) == 2


def test_shape_mismatch_rejected(tmp_path):
    save_checkpoint(tmp_path, 1, _state())
    bad_like = {"params": {"w": jax.ShapeDtypeStruct((4, 4), jnp.float32),
                           "b": jax.ShapeDtypeStruct((16,), jnp.float32)},
                "step": jax.ShapeDtypeStruct((), jnp.int32)}
    try:
        restore_checkpoint(tmp_path, bad_like)
        assert False, "should have raised"
    except ValueError:
        pass


def test_manifest_records_structure(tmp_path):
    save_checkpoint(tmp_path, 4, _state())
    man = json.loads((tmp_path / "step_000004" / "manifest.json").read_text())
    assert man["step"] == 4
    assert len(man["leaves"]) == 3
