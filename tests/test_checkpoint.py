"""Checkpoint roundtrip, atomicity, async save, elastic restore,
corrupt/partial-save rejection and fallback."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointCorruptError, available_steps,
                              latest_step, load_checkpoint_arrays,
                              restore_checkpoint, save_checkpoint)


def _state(seed=0):
    key = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(key, (8, 16)),
                       "b": jnp.zeros((16,))},
            "step": jnp.int32(7)}


def test_roundtrip(tmp_path):
    state = _state()
    save_checkpoint(tmp_path, 7, state)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        state)
    restored, step = restore_checkpoint(tmp_path, like)
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_pointer_tracks_newest(tmp_path):
    save_checkpoint(tmp_path, 1, _state(1))
    save_checkpoint(tmp_path, 5, _state(2))
    assert latest_step(tmp_path) == 5
    restored, step = restore_checkpoint(
        tmp_path, jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), _state()))
    assert step == 5


def test_async_save_completes(tmp_path):
    t = save_checkpoint(tmp_path, 3, _state(), async_=True)
    t.join()
    assert latest_step(tmp_path) == 3


def test_corrupt_tmp_dir_never_published(tmp_path):
    save_checkpoint(tmp_path, 2, _state())
    # leftover tmp dirs (simulating a crash mid-save) are invisible
    (tmp_path / ".tmp_step_000009_123").mkdir()
    assert latest_step(tmp_path) == 2


def test_shape_mismatch_rejected(tmp_path):
    save_checkpoint(tmp_path, 1, _state())
    bad_like = {"params": {"w": jax.ShapeDtypeStruct((4, 4), jnp.float32),
                           "b": jax.ShapeDtypeStruct((16,), jnp.float32)},
                "step": jax.ShapeDtypeStruct((), jnp.int32)}
    try:
        restore_checkpoint(tmp_path, bad_like)
        assert False, "should have raised"
    except ValueError:
        pass


def test_manifest_records_structure(tmp_path):
    save_checkpoint(tmp_path, 4, _state())
    man = json.loads((tmp_path / "step_000004" / "manifest.json").read_text())
    assert man["step"] == 4
    assert len(man["leaves"]) == 3


def test_meta_roundtrips_through_manifest(tmp_path):
    meta = {"format": "test-v1", "shards_seen": [0, 2],
            "ewa": 1.25, "cache": [{"sid": 3, "ub_scale": 0.5}]}
    save_checkpoint(tmp_path, 2, _state(), meta=meta)
    step, manifest, leaves = load_checkpoint_arrays(tmp_path)
    assert step == 2
    assert manifest["meta"] == meta
    assert len(leaves) == 3
    # float64 leaves come back as host numpy, bit-exact, NOT device_put
    save_checkpoint(tmp_path, 3, [np.array([1e-17, 1.0], np.float64)])
    _, _, (led,) = load_checkpoint_arrays(tmp_path)
    assert led.dtype == np.float64 and led[0] == 1e-17


def test_available_steps_lists_published_only(tmp_path):
    for s in (1, 9, 4):
        save_checkpoint(tmp_path, s, _state())
    (tmp_path / ".tmp_step_000077_1").mkdir()
    assert available_steps(tmp_path) == [1, 4, 9]


def test_corrupt_latest_falls_back_to_previous_complete(tmp_path):
    save_checkpoint(tmp_path, 1, _state(1))
    save_checkpoint(tmp_path, 2, _state(2))
    # truncate the newest shard file: a torn/partial write
    (tmp_path / "step_000002" / "shard_0.npz").write_bytes(b"not an npz")
    with pytest.raises(CheckpointCorruptError):
        load_checkpoint_arrays(tmp_path)          # fallback off: rejected
    step, _, leaves = load_checkpoint_arrays(tmp_path, fallback=True)
    assert step == 1
    np.testing.assert_array_equal(
        leaves[1], np.asarray(_state(1)["params"]["w"]))
    # the pytree-level restore takes the same fallback
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        _state())
    _, step = restore_checkpoint(tmp_path, like, fallback=True)
    assert step == 1


def test_corrupt_manifest_falls_back(tmp_path):
    save_checkpoint(tmp_path, 3, _state(3))
    save_checkpoint(tmp_path, 6, _state(6))
    (tmp_path / "step_000006" / "manifest.json").write_text("{ nope")
    step, _, _ = load_checkpoint_arrays(tmp_path, fallback=True)
    assert step == 3


def test_missing_shard_file_falls_back(tmp_path):
    save_checkpoint(tmp_path, 5, _state())
    save_checkpoint(tmp_path, 8, _state())
    (tmp_path / "step_000008" / "shard_0.npz").unlink()
    step, _, _ = load_checkpoint_arrays(tmp_path, fallback=True)
    assert step == 5


def test_every_step_corrupt_raises(tmp_path):
    save_checkpoint(tmp_path, 1, _state())
    (tmp_path / "step_000001" / "manifest.json").unlink()
    with pytest.raises(CheckpointCorruptError):
        load_checkpoint_arrays(tmp_path, fallback=True)


def test_no_checkpoint_raises_filenotfound(tmp_path):
    with pytest.raises(FileNotFoundError):
        load_checkpoint_arrays(tmp_path / "empty")
