"""End-to-end system behaviour: the paper's pipeline as a user sees it."""
import subprocess
import sys
import os

import jax
import numpy as np

from repro.core import KMeans
from repro.data import make_points

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_kpynq_end_to_end_clusters_blobs():
    """Well-separated blobs must be recovered (ARI-style purity check)."""
    pts, centers, truth = make_points(5000, 16, 12, seed=1,
                                      cluster_std=0.5, spread=20.0)
    km = KMeans(n_clusters=12, algorithm="yinyang", seed=0).fit(pts)
    # purity: each found cluster dominated by one true label
    labels = km.labels_
    purity = 0
    for c in range(12):
        members = truth[labels == c]
        if len(members):
            purity += np.bincount(members, minlength=12).max()
    assert purity / len(truth) > 0.95


def test_speedup_workload_reduction_scales_with_k():
    """The paper's thesis: work saving grows with K (more centroids ->
    more filterable distance evaluations)."""
    pts, _, _ = make_points(8000, 16, 64, seed=3)
    ratios = []
    for k in (8, 64):
        km_y = KMeans(n_clusters=k, algorithm="yinyang", seed=0).fit(pts)
        km_l = KMeans(n_clusters=k, algorithm="lloyd", seed=0).fit(pts)
        ratios.append(km_y.distance_evals_ / km_l.distance_evals_)
    assert ratios[1] < ratios[0]


def test_train_launcher_cli(tmp_path):
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch",
         "musicgen-medium", "--reduced", "--steps", "6", "--batch", "2",
         "--seq", "32", "--ckpt-dir", str(tmp_path)],
        env={**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")},
        capture_output=True, text=True, timeout=540)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "loss" in out.stdout


def test_serve_example_cli():
    """The serving demo end to end: a live streaming fit publishing
    into the index while the engine answers queries — the epoch must
    visibly advance and traffic must move."""
    out = subprocess.run(
        [sys.executable, os.path.join(ROOT, "examples",
                                      "serve_kmeans.py"), "--smoke"],
        env={**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")},
        capture_output=True, text=True, timeout=540)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "pts/s" in out.stdout
    assert "epoch ->" in out.stdout
