"""Per-architecture smoke tests (reduced configs) + decode consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_configs
from repro.models import (decode_step, forward, init_cache, init_params,
                          loss_fn, param_shapes)

ALL_ARCHS = list_configs()


def _batch(cfg, b=2, s=32, seed=0):
    key = jax.random.PRNGKey(seed)
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab),
             "labels": jax.random.randint(key, (b, s), 0, cfg.vocab)}
    if cfg.n_vision_tokens:
        batch["vision_embeds"] = jax.random.normal(
            key, (b, cfg.n_vision_tokens, cfg.d_model), cfg.compute_dtype)
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_smoke_forward_and_grad(arch):
    cfg = get_config(arch).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    loss, grads = jax.value_and_grad(loss_fn)(params, batch, cfg)
    assert np.isfinite(float(loss))
    for leaf in jax.tree.leaves(grads):
        assert np.isfinite(np.asarray(leaf, dtype=np.float32)).all()
    h = forward(params, batch["tokens"], cfg,
                vision_embeds=batch.get("vision_embeds"))
    assert h.shape == (2, 32, cfg.d_model)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_smoke_decode_shapes(arch):
    cfg = get_config(arch).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    cache = init_cache(cfg, 2, 16)
    tok = jnp.zeros((2, 1), jnp.int32)
    logits, new_cache = decode_step(params, cache, tok, 0, cfg)
    assert logits.shape == (2, 1, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits)).all()
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)


@pytest.mark.parametrize("arch", ["qwen2-7b", "phi4-mini-3.8b",
                                  "minicpm3-4b", "mamba2-780m",
                                  "hymba-1.5b", "musicgen-medium",
                                  "mistral-nemo-12b",
                                  "llava-next-mistral-7b"])
def test_decode_matches_train_forward(arch):
    cfg = get_config(arch).reduced()
    params = init_params(jax.random.PRNGKey(1), cfg)
    b, s = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0, cfg.vocab)
    vis = None
    if cfg.n_vision_tokens:
        # decode path has no vision merge; compare text-only
        cfg = dataclasses.replace(cfg, n_vision_tokens=0)
    h = forward(params, toks, cfg, vision_embeds=vis)
    lm = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits_train = jnp.einsum("bsd,dv->bsv", h, lm.astype(h.dtype))
    cache = init_cache(cfg, b, s)
    outs = []
    for t in range(s):
        lg, cache = decode_step(params, cache, toks[:, t:t + 1], t, cfg)
        outs.append(lg[:, 0])
    logits_dec = jnp.stack(outs, axis=1)
    scale = float(jnp.max(jnp.abs(logits_train))) + 1e-9
    err = float(jnp.max(jnp.abs(logits_train - logits_dec))) / scale
    assert err < 2e-2, err


def test_moe_decode_matches_with_ample_capacity():
    cfg = dataclasses.replace(get_config("qwen3-moe-235b-a22b").reduced(),
                              moe_capacity_factor=100.0)
    params = init_params(jax.random.PRNGKey(0), cfg)
    b, s = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(3), (b, s), 0, cfg.vocab)
    h = forward(params, toks, cfg)
    logits_train = jnp.einsum("bsd,dv->bsv", h,
                              params["lm_head"].astype(h.dtype))
    cache = init_cache(cfg, b, s)
    outs = []
    for t in range(s):
        lg, cache = decode_step(params, cache, toks[:, t:t + 1], t, cfg)
        outs.append(lg[:, 0])
    err = float(jnp.max(jnp.abs(logits_train - jnp.stack(outs, 1))))
    assert err < 1e-3, err


def test_prefill_matches_decode_continuation():
    from repro.models import prefill_forward
    for arch in ("qwen2-7b", "mamba2-780m", "minicpm3-4b", "hymba-1.5b"):
        cfg = get_config(arch).reduced()
        params = init_params(jax.random.PRNGKey(4), cfg)
        b, s = 2, 8
        toks = jax.random.randint(jax.random.PRNGKey(5), (b, s + 1),
                                  0, cfg.vocab)
        logits_pf, cache = prefill_forward(params, toks[:, :s], cfg)
        # pad seq-dim leaves out by one for the next token
        def pad1(leaf):
            if leaf.ndim >= 3 and leaf.shape[2] == s:
                pad = [(0, 0)] * leaf.ndim
                pad[2] = (0, 1)
                return jnp.pad(leaf, pad)
            return leaf
        cache = jax.tree.map(pad1, cache)
        lg_dec, _ = decode_step(params, cache, toks[:, s:s + 1], s, cfg)
        # decode at position s from prefilled cache == one more training
        # position: compare against full train forward shifted
        h = forward(params, toks, cfg)
        lm = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits_train = jnp.einsum("bsd,dv->bsv", h, lm.astype(h.dtype))
        scale = float(jnp.max(jnp.abs(logits_train))) + 1e-9
        err_pf = float(jnp.max(jnp.abs(
            logits_pf[:, 0] - logits_train[:, s - 1]))) / scale
        err_dec = float(jnp.max(jnp.abs(
            lg_dec[:, 0] - logits_train[:, s]))) / scale
        assert err_pf < 2e-2, (arch, err_pf)
        assert err_dec < 2e-2, (arch, err_dec)


def test_param_shapes_match_materialized():
    for arch in ALL_ARCHS[:3]:
        cfg = get_config(arch).reduced()
        shapes = param_shapes(cfg)
        params = init_params(jax.random.PRNGKey(0), cfg)
        flat_s = jax.tree.leaves(
            shapes, is_leaf=lambda x: isinstance(x, tuple))
        flat_p = jax.tree.leaves(params)
        assert len(flat_s) == len(flat_p)
        for s_, p_ in zip(flat_s, flat_p):
            assert tuple(s_) == tuple(p_.shape)


def test_vocab_padding_multiple_of_256():
    for arch in ALL_ARCHS:
        cfg = get_config(arch)
        assert cfg.padded_vocab % 256 == 0
        assert cfg.padded_vocab >= cfg.vocab
        assert cfg.padded_vocab - cfg.vocab < 256


def test_int8_kv_cache_decode_close_to_native():
    cfg = get_config("qwen2-7b").reduced()
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    params = init_params(jax.random.PRNGKey(0), cfg)
    b, s = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
    h = forward(params, toks, cfg)
    lt = jnp.einsum("bsd,dv->bsv", h, params["lm_head"].astype(h.dtype))
    cache = init_cache(cfg8, b, s)
    assert cache["k"].dtype == jnp.int8
    outs = []
    for t in range(s):
        lg, cache = decode_step(params, cache, toks[:, t:t + 1], t, cfg8)
        outs.append(lg[:, 0])
    ld = jnp.stack(outs, 1)
    rel = float(jnp.max(jnp.abs(lt - ld))) / float(jnp.max(jnp.abs(lt)))
    assert rel < 0.05, rel
