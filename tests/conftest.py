"""Shared test fixtures.

Every test runs against an isolated, empty tuning cache: the engine's
default ``tune="auto"`` consults ``~/.cache/repro_kmeans_tune.json``
(or ``$REPRO_KMEANS_TUNE_CACHE``), and letting developer-machine /
benchmark-produced entries leak into tests would make backend-routing
assertions depend on ``$HOME`` state. Results can never change (tuning
is wall-clock-only), but routing/stats assertions can.
"""
import pytest


@pytest.fixture(autouse=True)
def _isolated_tune_cache(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_KMEANS_TUNE_CACHE",
                       str(tmp_path / "tune_cache.json"))
    import repro.tune as tune
    tune.set_default_cache(None)     # re-resolve under the tmp env var
    yield
    tune.set_default_cache(None)     # drop the tmp-backed singleton


@pytest.fixture(autouse=True, scope="module")
def _bounded_xla_executable_maps():
    """Drop compiled executables between test modules.

    Every XLA:CPU compilation mmaps JIT code pages that live as long as
    the executable does; a full-suite run accumulates tens of thousands
    of mappings and a single process runs into ``vm.max_map_count``
    (65530 by default) — at which point LLVM's mmap fails with ENOMEM
    and the JIT segfaults. Tests never share compilations across module
    boundaries, so clearing jit caches per module keeps the mapping
    count bounded at no meaningful recompile cost.
    """
    yield
    import jax
    jax.clear_caches()
