"""Shared test fixtures.

Every test runs against an isolated, empty tuning cache: the engine's
default ``tune="auto"`` consults ``~/.cache/repro_kmeans_tune.json``
(or ``$REPRO_KMEANS_TUNE_CACHE``), and letting developer-machine /
benchmark-produced entries leak into tests would make backend-routing
assertions depend on ``$HOME`` state. Results can never change (tuning
is wall-clock-only), but routing/stats assertions can.
"""
import pytest


@pytest.fixture(autouse=True)
def _isolated_tune_cache(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_KMEANS_TUNE_CACHE",
                       str(tmp_path / "tune_cache.json"))
    import repro.tune as tune
    tune.set_default_cache(None)     # re-resolve under the tmp env var
    yield
    tune.set_default_cache(None)     # drop the tmp-backed singleton
