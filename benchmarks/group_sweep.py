"""Tunable-parameters ablation (the paper's configurability claim):
work reduction vs the group count G and vs K — reproducing the two
scaling laws the multi-level filter depends on:

  * G=1 (point-level only) -> Hamerly; G up to ~K/4 strengthens the
    group filter until bound-maintenance overhead dominates.
  * Work reduction grows with K (more centroids = more filterable
    distance evaluations) — the reason the paper targets high-K.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import kmeans_plusplus, lloyd, yinyang
from repro.data import make_points


def main():
    print("name,us_per_call,derived")
    n, d = 32768, 32
    # --- sweep G at fixed K ---
    k = 128
    pts = jnp.asarray(make_points(n, d, k, seed=0)[0])
    init = kmeans_plusplus(jax.random.PRNGKey(1), pts, k)
    base = lloyd(pts, init, 40, 1e-4)
    for g in (1, 4, 13, 32, 64):
        r = yinyang(pts, init, n_groups=g, max_iters=40, tol=1e-4)
        wr = float(base.distance_evals) / float(r.distance_evals)
        print(f"group_sweep/K{k}_G{g},,work_red={wr:.2f}x "
              f"iters={int(r.n_iters)}")
    # --- sweep K at the default G=K/10 ---
    for k in (32, 128, 512):
        pts = jnp.asarray(make_points(n, d, k, seed=0)[0])
        init = kmeans_plusplus(jax.random.PRNGKey(1), pts, k)
        base = lloyd(pts, init, 30, 1e-4)
        r = yinyang(pts, init, max_iters=30, tol=1e-4)
        wr = float(base.distance_evals) / float(r.distance_evals)
        print(f"group_sweep/scalingK_{k},,work_red={wr:.2f}x")


if __name__ == "__main__":
    main()
