"""Serve-path benchmark: batched throughput + Poisson open-loop latency.

Measures the ``repro.serve`` subsystem end to end — queue, coalescing,
bucket padding, epoch swap, batched assign — in the two regimes that
matter for a live index:

* **saturation throughput**: closed-loop bulk requests (vector
  quantization / bulk re-labelling traffic) keep the engine's batch
  pipeline full; points/s is the headline that the ISSUE's >=8x-over-
  single-stream-predict criterion gates (``run.py --check``);
* **open-loop latency**: Poisson arrivals of small ragged query blocks
  at a fraction of saturation, with a CONCURRENT centroid publisher
  refreshing the index mid-load — p50/p99 per-request latency, epoch
  swaps observed by responses, and exact per-epoch oracle parity on
  sampled responses.

Writes the ``"serve"`` row of ``BENCH_kmeans.json``; ``--check`` gates
parity + the p99 ceiling (the CI serve lane) and exports the latency
histogram JSONL artifact.

  PYTHONPATH=src python -m benchmarks.serve_bench --scale 0.1 --check
"""
from __future__ import annotations

import argparse
import gc
import json
import os
import sys
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.kpynq import paper_suite
from repro.core import engine_fit, kmeans_plusplus, pairwise_sq_dists
from repro.data import make_points
from repro.obs import MetricsRegistry
from repro.serve import CentroidIndex, ServeEngine
from repro.tune import ServeConfig, lookup_serve


def _fit_centroids(prob, n):
    pts_np, _, _ = make_points(n, prob.n_dims, prob.k, seed=0)
    pts = jnp.asarray(pts_np)
    init = kmeans_plusplus(jax.random.PRNGKey(1), pts, prob.k)
    r = engine_fit(pts, init, n_groups=prob.n_groups, max_iters=20,
                   tol=prob.tol, backend="auto")
    out = np.asarray(r.centroids)
    # drop the fit's live buffers and compiled programs so the serve
    # phases measure a clean steady state, not allocator fragmentation
    del r, pts, init
    jax.clear_caches()
    gc.collect()
    return out


def run(scale=1.0, dataset="uci-medium", *, duration_s=1.0,
        req_points=512, load=0.25, publishes=5, config=None,
        registry=None):
    prob = next(p for p in paper_suite if p.name == dataset)
    n = max(int(prob.n_points * scale), 2048)
    d, k = prob.n_dims, prob.k
    centroids = _fit_centroids(prob, n)

    reg = registry or MetricsRegistry()
    # tuned entry wins; otherwise the bench's saturation-oriented default
    # (deep batches amortize per-batch dispatch on the hot path)
    cfg = config or lookup_serve(k=k, d=d) or ServeConfig(max_batch=16384)
    index = CentroidIndex(centroids, obs=reg)
    rng = np.random.default_rng(7)
    pool, _, _ = make_points(max(4 * cfg.max_batch, 2 * n), d, k, seed=9)
    pool = np.ascontiguousarray(pool, np.float32)

    lat_ms: list = []
    sampled: list = []          # (query slice, labels, epoch) for parity
    epoch_centroids = {1: centroids}

    with ServeEngine(index, config=cfg, tune="off", obs=reg) as eng:
        # warm every bucket once so neither phase measures compiles
        for b in _buckets(cfg):
            eng.assign(pool[:b])

        # -- phase 1: closed-loop saturation (bulk requests) -------------
        # Device-resident request blocks, pre-staged OUTSIDE the timed
        # region — exactly the regime predict_bench measures in (its
        # pts are jnp.asarray'd once before the timed loop), so the
        # serve/predict ratio compares the two paths' compute, not a
        # host staging copy the predict row never pays. Each block is
        # exactly max_batch, so the engine's exact-fit path hands it
        # straight to the jitted assign (the zero-copy device-resident
        # submit). Host numpy traffic — which DOES pay one staging
        # copy per request — is what the open-loop phase measures.
        blocks = 4
        total = blocks * cfg.max_batch
        parts = [jnp.asarray(pool[i * cfg.max_batch:
                                  (i + 1) * cfg.max_batch])
                 for i in range(blocks)]
        for p in parts:
            p.block_until_ready()
        for f in [eng.submit(p) for p in parts]:
            f.result()                  # warm the parts into cache
        sat_s = float("inf")
        for _ in range(5):
            t0 = time.perf_counter()
            for f in [eng.submit(p) for p in parts]:
                f.result()
            sat_s = min(sat_s, time.perf_counter() - t0)
        pps = total / sat_s

        # -- phase 2: Poisson open-loop + concurrent refresh --------------
        rate = float(np.clip(load * pps / req_points, 100.0, 2500.0))
        stop_pub = threading.Event()

        def publisher():
            # small perturbations: the drift-ledger reuse path plus
            # genuinely different labels per epoch
            cur = centroids.copy()
            for _ in range(publishes):
                if stop_pub.wait(duration_s / (publishes + 1)):
                    return
                cur = cur + rng.standard_normal(
                    cur.shape).astype(np.float32) * 0.05
                ep = index.publish(cur)
                epoch_centroids[ep] = cur.copy()

        pub_t = threading.Thread(target=publisher)
        pub_t.start()
        pend = []
        done_at: dict = {}
        t_start = time.perf_counter()
        next_arrival = t_start
        i_req = 0
        while True:
            now = time.perf_counter()
            if now - t_start >= duration_s:
                break
            if now < next_arrival:
                time.sleep(min(next_arrival - now, 0.002))
                continue
            sched = next_arrival
            next_arrival += rng.exponential(1.0 / rate)
            lo = (i_req * 37) % (pool.shape[0] - req_points)
            fut = eng.submit(pool[lo:lo + req_points])
            # completion stamped by the engine thread's set_result, not
            # by whenever this thread gets around to reading the future
            fut.add_done_callback(
                lambda f, i=i_req: done_at.__setitem__(
                    i, time.perf_counter()))
            pend.append((i_req, sched, lo, fut))
            i_req += 1
        for i, sched, lo, fut in pend:
            fut.result()
        stop_pub.set()
        pub_t.join()
        for i, sched, lo, fut in pend:
            # open-loop latency is vs the SCHEDULED arrival — queueing
            # delay from falling behind the arrival process counts
            lat_ms.append((done_at[i] - sched) * 1e3)
            if i % 29 == 0:
                labels, epoch = fut.result()
                sampled.append((lo, labels, epoch))

    # -- exactness: every sampled response vs ITS epoch's oracle ---------
    parity = True
    oracles: dict = {}
    for lo, labels, epoch in sampled:
        if epoch not in oracles:
            oracles[epoch] = jnp.asarray(epoch_centroids[epoch])
        ref = np.asarray(jnp.argmin(pairwise_sq_dists(
            jnp.asarray(pool[lo:lo + req_points]), oracles[epoch]),
            axis=1))
        parity &= bool(np.array_equal(labels, ref))

    lat = np.sort(np.asarray(lat_ms))
    epochs_seen = sorted({e for _, _, e in sampled})
    return {
        "dataset": f"{dataset}-serve", "n": n, "d": d, "k": k,
        "backend": cfg.backend, "chunk": cfg.chunk,
        "max_batch": cfg.max_batch,
        "points_per_sec": pps,
        "p50_ms": float(lat[int(0.50 * (len(lat) - 1))]) if len(lat) else 0.0,
        "p99_ms": float(lat[int(0.99 * (len(lat) - 1))]) if len(lat) else 0.0,
        "requests": len(lat),
        "offered_rps": rate, "req_points": req_points,
        "publishes": index.publishes,
        "table_rebuilds": index.rebuilds,
        "table_reuses": index.reuses,
        "epochs_seen": len(epochs_seen),
        "labels_match_dense": parity,
    }, lat


def _buckets(cfg: ServeConfig):
    b, out = cfg.min_bucket, []
    while b <= cfg.max_batch:
        out.append(b)
        b *= 2
    return out


def write_json(row, path="BENCH_kmeans.json"):
    """Merge the serve record into the shared perf JSON."""
    payload = {}
    if os.path.exists(path):
        with open(path) as fh:
            payload = json.load(fh)
    payload["serve"] = row
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=2)
    return path


def write_histogram(lat_ms: np.ndarray, path: str) -> str:
    """Latency histogram JSONL (the CI serve-lane artifact): log-spaced
    bucket rows + one summary row."""
    edges = np.logspace(-1, 2.5, 36)      # 0.1ms .. ~316ms
    counts, _ = np.histogram(lat_ms, bins=edges)
    with open(path, "w") as fh:
        for lo, hi, c in zip(edges[:-1], edges[1:], counts):
            fh.write(json.dumps({"le_ms": round(float(hi), 4),
                                 "ge_ms": round(float(lo), 4),
                                 "count": int(c)}) + "\n")
        if len(lat_ms):
            fh.write(json.dumps({
                "summary": True, "n": int(len(lat_ms)),
                "p50_ms": float(np.percentile(lat_ms, 50)),
                "p99_ms": float(np.percentile(lat_ms, 99)),
                "max_ms": float(lat_ms.max())}) + "\n")
    return path


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--out", default="BENCH_kmeans.json",
                    help="perf JSON to merge the serve row into "
                         "('' disables)")
    ap.add_argument("--duration", type=float, default=1.0,
                    help="open-loop latency phase duration (s)")
    ap.add_argument("--check", action="store_true",
                    help="gate: exact parity + p99 ceiling; exit 1 on "
                         "failure")
    ap.add_argument("--p99-ceiling-ms", type=float, default=50.0,
                    help="--check fails when p99 exceeds this")
    ap.add_argument("--hist-out", default="obs_serve_latency.jsonl",
                    help="latency histogram JSONL ('' disables)")
    args = ap.parse_args(argv)

    row, lat = run(scale=args.scale, duration_s=args.duration)
    print("name,us_per_call,derived")
    print(f"serve/{row['dataset']},{1e6 * row['max_batch'] / row['points_per_sec']:.1f},"
          f"pps={row['points_per_sec']:.0f} p50={row['p50_ms']:.2f}ms "
          f"p99={row['p99_ms']:.2f}ms backend={row['backend']} "
          f"epochs={row['epochs_seen']} "
          f"parity={'OK' if row['labels_match_dense'] else 'FAIL'}")
    if args.hist_out:
        print(f"serve: latency histogram -> "
              f"{write_histogram(lat, args.hist_out)}")
    if args.out:
        write_json(row, args.out)
    if args.check:
        ok = True
        if not row["labels_match_dense"]:
            print("serve: PARITY FAILED vs per-epoch dense oracle")
            ok = False
        if row["p99_ms"] > args.p99_ceiling_ms:
            print(f"serve: p99 {row['p99_ms']:.2f}ms exceeds ceiling "
                  f"{args.p99_ceiling_ms:.1f}ms")
            ok = False
        if row["points_per_sec"] <= 0 or row["requests"] == 0:
            print("serve: no traffic served")
            ok = False
        print(f"serve: check {'OK' if ok else 'FAILED'}")
        sys.exit(0 if ok else 1)
    return row


if __name__ == "__main__":
    main()
