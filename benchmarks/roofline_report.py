"""Roofline table from the dry-run JSON cache (results/dryrun/)."""
from __future__ import annotations

import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def load(mesh="16x16", tag=""):
    rows = []
    for f in sorted(RESULTS.glob(f"*__{mesh}{tag}.json")):
        r = json.loads(f.read_text())
        if tag == "" and r.get("tag"):
            continue
        rows.append(r)
    return rows


def fmt_row(r):
    if not r.get("ok"):
        return (f"{r['arch']:26s} {r['shape']:12s} FAILED: "
                f"{r.get('error', '')[:60]}")
    rl = r["roofline"]
    return (f"{r['arch']:26s} {r['shape']:12s} "
            f"C={rl['t_compute_s']:9.3e} M={rl['t_memory_s']:9.3e} "
            f"N={rl['t_collective_s']:9.3e} dom={rl['bottleneck']:10s} "
            f"useful={rl.get('useful_flops_ratio', 0):6.3f} "
            f"roofline={rl.get('roofline_fraction', 0):7.4f}")


def main():
    print("name,us_per_call,derived")
    for mesh in ("16x16", "2x16x16"):
        rows = load(mesh)
        for r in rows:
            if r.get("ok"):
                rl = r["roofline"]
                t_star = max(rl["t_compute_s"], rl["t_memory_s"],
                             rl["t_collective_s"])
                print(f"roofline/{mesh}/{r['arch']}/{r['shape']},"
                      f"{t_star * 1e6:.0f},"
                      f"dom={rl['bottleneck']} "
                      f"frac={rl.get('roofline_fraction', 0):.4f}")
            else:
                print(f"roofline/{mesh}/{r['arch']}/{r['shape']},,FAILED")


if __name__ == "__main__":
    main()
