"""Benchmark harness: one section per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--out PATH]
  PYTHONPATH=src python -m benchmarks.run --quick --tune   # retune first
  PYTHONPATH=src python -m benchmarks.run --check        # CI perf gate

Prints ``name,us_per_call,derived`` CSV per line, and writes the
K-means perf record to ``BENCH_kmeans.json`` (per-dataset ``lloyd_ms``,
``engine_ms``, ``speedup``, ``work_reduction``, winning ``tuned``
config + suite means, plus the ``streaming`` and ``distributed``
subsystem records — the latter measured in a
``benchmarks.distributed_bench`` subprocess so the forced multi-device
CPU runtime can initialise) so the perf trajectory is tracked across
PRs.

``--tune`` refreshes the engine's per-(platform, N, K, D) tuning cache
(``benchmarks/autotune.py`` -> :mod:`repro.tune`) for the suite's
problem signatures BEFORE measuring, so the ``engine`` rows run the
tuned configurations.

``--check`` is the regression gate:

* re-measures the quick suite and compares ``mean_speedup`` against
  the committed record (within ``--check-tolerance``, timing noise
  being what it is);
* requires the COMMITTED record itself to show the engine at no worse
  than 5% behind Lloyd (``engine_ms <= lloyd_ms * 1.05 + 0.25``; the
  absolute term is the wrapper's fixed dispatch cost, visible only on
  sub-ms rows) on every quick-suite dataset — the deterministic
  wall-clock contract of ISSUE 3 (the engine's work-efficiency must
  not cost wall-clock);
* requires the streaming fit's inertia gap to stay within 5% of the
  batch engine;
* requires the committed ``distributed`` record (when present) to keep
  compact/dense parity and a per-shard work reduction > 1.0;
* smoke-measures the tiled predict path (``predict_bench``): exact
  parity with the dense argmin gates, and fresh throughput must stay
  above the committed row * ``--check-tolerance`` (the drift gate —
  the committed predict row is a real baseline, not a log line);
* measures the serving subsystem (``serve_bench``): per-epoch oracle
  parity under a concurrent publisher gates, the COMMITTED serve row
  must show >= 8x the committed predict row's points/s (the ISSUE 10
  tentpole claim), fresh serve throughput must stay above the
  committed row * tolerance, and the open-loop p99 must stay under
  a machine-aware ceiling (max of ``--serve-p99-ceiling-ms`` and the
  committed row's p99 / tolerance);
* runs the deterministic weighted-parity gate: uniform ``sample_weight``
  bit-identical to unweighted on every backend, integer weights ==
  duplicated points.

* runs the telemetry-overhead gate: ``engine_ms`` with the telemetry
  ring on must stay within 3% (+0.5ms absolute, timer floor) of the
  ring off, interleaved best-of — observability must be ~free;
* requires the committed record to carry its ``provenance`` block
  (git sha, jax version, platform, device count, timestamp) and a
  ``telemetry`` summary per dataset row.

Every gate reports through one :class:`repro.obs.MetricsRegistry`
(gauge ``check_gate_ok{gate=...}`` + a ``gate`` event each), so every
failure names itself — including the streaming-only exit-3 path — and
the whole run exports ``obs_events.jsonl`` / ``obs_metrics.prom`` plus
a Perfetto trace dir (``obs_trace/``) as CI artifacts.

Exit codes are per-gate so CI logs say which tripped: 0 = all OK,
1 = any engine-side gate regressed (the ``gate[...]`` lines name
them), **3 = ONLY the streaming inertia gap regressed** (speedups all
healthy — a subsystem-specific failure, not an engine regression),
2 = no committed record.
"""
import argparse
import sys


def weighted_parity_gate() -> bool:
    """Deterministic sample-weight gate: uniform weights must be
    BIT-IDENTICAL to the unweighted fit on every engine backend, and
    integer weights must land on the duplicated-points fixed point.
    Pure correctness (no timing), so it either holds or the weight
    threading regressed."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import engine_fit, kmeans_plusplus
    from repro.data import make_points

    pts_np, _, _ = make_points(1200, 8, 12, seed=0)
    pts = jnp.asarray(pts_np)
    init = kmeans_plusplus(jax.random.PRNGKey(1), pts, 12)
    ok = True
    for backend in ("oracle", "compact", "lloyd"):
        r0 = engine_fit(pts, init, max_iters=30, tol=1e-5,
                        backend=backend, tune="off")
        r1 = engine_fit(pts, init, max_iters=30, tol=1e-5,
                        backend=backend, tune="off",
                        sample_weight=jnp.ones((1200,)))
        bit = np.array_equal(np.asarray(r0.assignments),
                             np.asarray(r1.assignments)) and \
            float(r0.inertia) == float(r1.inertia)
        ok &= bit
        print(f"check: weighted-parity uniform/{backend}: "
              f"{'OK' if bit else 'REGRESSION'}")
    rng = np.random.default_rng(0)
    wts = rng.integers(1, 4, size=1200)
    r_w = engine_fit(pts, init, max_iters=40, tol=1e-6,
                     backend="compact", tune="off",
                     sample_weight=jnp.asarray(wts, jnp.float32))
    r_d = engine_fit(jnp.asarray(np.repeat(pts_np, wts, axis=0)), init,
                     max_iters=40, tol=1e-6, backend="compact",
                     tune="off")
    dup = bool(np.allclose(np.asarray(r_w.centroids),
                           np.asarray(r_d.centroids), atol=1e-3))
    ok &= dup
    print(f"check: weighted-parity duplication==int-weights: "
          f"{'OK' if dup else 'REGRESSION'}")
    return ok


def telemetry_overhead_gate(registry):
    """Observability must be ~free: interleaved best-of wall-clock of
    the same engine fit with the telemetry ring ON (incl. the one-shot
    drain + stats build) vs OFF. Gate: ``on <= off * 1.03 + 0.5ms``
    (the absolute term is the timer/dispatch floor on sub-ms fits).
    Returns ``(ok, detail_str, off_s, on_s)``."""
    import time

    import jax
    import jax.numpy as jnp

    from repro.core import engine_fit, kmeans_plusplus
    from repro.data import make_points
    from repro.obs import ObsConfig

    pts_np, _, _ = make_points(8000, 16, 32, seed=0)
    pts = jnp.asarray(pts_np)
    init = kmeans_plusplus(jax.random.PRNGKey(1), pts, 32)
    obs_cfg = ObsConfig(registry=registry)
    # return_stats on BOTH sides: stats construction predates obs, so
    # the measured delta is exactly the telemetry (ring threading +
    # one-shot drain + registry publish), not the stats object
    kw = dict(max_iters=25, tol=0.0, backend="compact", tune="off",
              return_stats=True)

    def run_off():
        r, _ = engine_fit(pts, init, **kw)
        jax.block_until_ready(r.centroids)

    def run_on():
        r, _ = engine_fit(pts, init, obs=obs_cfg, **kw)
        jax.block_until_ready(r.centroids)

    run_off(), run_on()                   # compile + warm caches
    best = [float("inf"), float("inf")]
    done, spent = 0, 0.0
    # deep sampling: the delta under test is sub-ms, so the best-of
    # must actually reach both floors or noise decides the gate
    while done < 20 or (spent < 3.0 and done < 60):
        for j, f in enumerate((run_off, run_on)):
            t0 = time.perf_counter()
            f()
            dt = time.perf_counter() - t0
            best[j] = min(best[j], dt)
            spent += dt
        done += 1
    t_off, t_on = best
    ok = t_on <= t_off * 1.03 + 0.5e-3
    detail = (f"off={t_off * 1e3:.2f}ms on={t_on * 1e3:.2f}ms "
              f"ratio={t_on / max(t_off, 1e-12):.3f} "
              f"(limit 1.03 + 0.5ms)")
    return ok, detail, t_off, t_on


def check(args) -> None:
    import json

    from repro.obs import MetricsRegistry, profile

    from . import (kmeans_speedup, predict_bench, resilience_bench,
                   serve_bench, streaming_bench)

    reg = MetricsRegistry()
    gates: dict = {}          # name -> ok, in report order

    def gate(name: str, ok, detail: str = "") -> bool:
        """Single reporting funnel: every gate lands in the registry
        (gauge + event) AND prints one self-naming line."""
        ok = bool(ok)
        gates[name] = ok
        reg.gauge("check_gate_ok", "1 = perf gate passed",
                  labels={"gate": name}).set(1.0 if ok else 0.0)
        reg.log_event("gate", gate=name, ok=ok, detail=detail)
        print(f"check: gate[{name}] {'OK' if ok else 'REGRESSION'}"
              + (f" ({detail})" if detail else ""))
        return ok

    def export_artifacts() -> None:
        """CI artifacts: the event log (every gate + every obs-enabled
        fit), the Prometheus snapshot, and a Perfetto trace of one
        engine fit carrying the kpynq/* phase annotations."""
        print(f"check: obs event log -> {reg.export_jsonl('obs_events.jsonl')}")
        print(f"check: obs metrics  -> "
              f"{reg.export_prometheus('obs_metrics.prom')}")

    def finish() -> None:
        export_artifacts()
        failed = [name for name, ok in gates.items() if not ok]
        if not failed:
            sys.exit(0)
        if failed == ["streaming-gap"]:
            # distinct code: ONLY the streaming subsystem tripped — the
            # engine gates above are all healthy, so CI can label the
            # failure precisely instead of reading it as a perf
            # regression
            print("check: FAILED gate(s): streaming-gap (exit 3)")
            sys.exit(3)
        print(f"check: FAILED gate(s): {', '.join(failed)} (exit 1)")
        sys.exit(1)

    try:
        with open(args.json) as fh:
            committed = json.load(fh)
    except FileNotFoundError:
        print(f"check: no committed record at {args.json}; run the "
              f"benchmark first", file=sys.stderr)
        reg.log_event("gate", gate="committed-record", ok=False,
                      detail=f"missing {args.json}")
        reg.export_jsonl("obs_events.jsonl")
        sys.exit(2)

    # the committed record must say where it came from and what the
    # engine did per dataset — both deterministic record-shape gates
    prov = committed.get("provenance") or {}
    gate("provenance",
         isinstance(prov, dict) and "git_sha" in prov
         and "jax_version" in prov and "timestamp" in prov,
         f"git={prov.get('git_sha', 'MISSING')!s:.12} "
         f"jax={prov.get('jax_version', 'MISSING')}")
    gate("telemetry",
         bool(committed.get("datasets"))
         and all("telemetry" in r for r in committed["datasets"]),
         "per-dataset ring summaries present")

    # committed-record wall-clock gate: the engine row of every dataset
    # must be within 5% of its Lloyd baseline (deterministic — no
    # re-measurement; the record is only committed when it holds). The
    # 0.25ms absolute term covers the engine wrapper's fixed dispatch
    # overhead, which is structural (not a regression) on sub-ms
    # Lloyd-routed rows and negligible everywhere else.
    wall_ok = True
    worst = 0.0
    for row in committed.get("datasets", []):
        ratio = row["engine_ms"] / max(row["lloyd_ms"], 1e-9)
        worst = max(worst, ratio)
        ok = row["engine_ms"] <= row["lloyd_ms"] * 1.05 + 0.25
        wall_ok &= ok
        print(f"check: committed {row['dataset']}: engine/lloyd="
              f"{ratio:.3f} (limit 1.05 + 0.25ms) -> "
              f"{'OK' if ok else 'REGRESSION'}")
    gate("wall-clock", wall_ok,
         f"worst engine/lloyd={worst:.3f} (limit 1.05 + 0.25ms)")

    # committed distributed record: parity is structural and the
    # work reduction is the tentpole claim — both deterministic
    drow = committed.get("distributed")
    if drow:
        gate("distributed",
             drow.get("assignments_match", False)
             and drow.get("work_reduction", 0.0) > 1.0,
             f"parity={'OK' if drow.get('assignments_match') else 'FAIL'} "
             f"work_reduction={drow.get('work_reduction', 0.0):.2f}x "
             f"(must be > 1.0)")

    scale = committed.get("scale", 0.1)
    if args.tune:
        from . import autotune
        autotune.tune_suite(scale=scale)

    # re-measure at the committed record's scale: speedups at different
    # problem sizes are incommensurable (tiny fits auto-route to Lloyd)
    rows = kmeans_speedup.run(scale=scale)
    fresh = kmeans_speedup.summarize(rows)["mean_speedup"]
    committed_rows = {r["dataset"]: r for r in committed.get("datasets", [])}
    print("check: dataset            fresh   committed")
    for r in rows:
        ref_row = committed_rows.get(r["dataset"], {})
        print(f"check:   {r['dataset']:<16} "
              f"{r['speedup']:7.3f}x  "
              f"{ref_row.get('speedup', float('nan')):7.3f}x")
    ref = committed["mean_speedup"]
    floor = ref * args.check_tolerance
    gate("mean_speedup", fresh >= floor,
         f"fresh={fresh:.3f} committed={ref:.3f} (scale={scale}) "
         f"floor={floor:.3f}")

    # observability must not cost wall-clock: ring on vs off,
    # interleaved best-of, on the same compiled problem
    ov_ok, ov_detail, _, _ = telemetry_overhead_gate(reg)
    gate("telemetry-overhead", ov_ok, ov_detail)

    # predict row: the tiled PassCore assign must be exact (parity with
    # the dense argmin is structural), and fresh throughput must hold
    # the committed row within tolerance — the committed predict row is
    # the serve gate's 8x denominator, so drift here is gated, not
    # just logged
    prow = predict_bench.run(scale=scale)
    cpred = (committed.get("predict") or {}).get("points_per_sec", 0.0)
    pred_floor = cpred * args.check_tolerance
    gate("predict",
         prow["labels_match_dense"] and prow["points_per_sec"] > 0
         and prow["points_per_sec"] >= pred_floor,
         f"pps={prow['points_per_sec']:.0f} committed={cpred:.0f} "
         f"floor={pred_floor:.0f} parity="
         f"{'OK' if prow['labels_match_dense'] else 'FAIL'}")

    # serving subsystem: batched throughput + swap consistency.
    # serve-parity is structural (every sampled response must match
    # ITS OWN epoch's dense oracle exactly, under a concurrent
    # publisher). serve-throughput is the tentpole claim: the
    # COMMITTED serve row >= 8x the committed predict row
    # (deterministic, record-shape), and the fresh measurement must
    # hold the committed row within tolerance.
    svrow, _ = serve_bench.run(scale=scale)
    cserve = (committed.get("serve") or {}).get("points_per_sec", 0.0)
    ratio = cserve / max(cpred, 1e-9)
    serve_floor = cserve * args.check_tolerance
    gate("serve-parity",
         svrow["labels_match_dense"] and svrow["requests"] > 0
         and svrow["epochs_seen"] >= 1,
         f"parity={'OK' if svrow['labels_match_dense'] else 'FAIL'} "
         f"requests={svrow['requests']} epochs={svrow['epochs_seen']}")
    gate("serve-throughput",
         cserve > 0 and ratio >= 8.0
         and svrow["points_per_sec"] >= serve_floor,
         f"committed serve/predict={ratio:.2f}x (need >=8) "
         f"fresh={svrow['points_per_sec']:.0f} floor={serve_floor:.0f}")
    # p99 is the one wall-clock-fresh latency gate, so it must absorb
    # shared-runner noise: the ceiling is the committed row's p99
    # widened by the check tolerance, floored at --serve-p99-ceiling-ms
    # so a very fast committed row never produces a hair-trigger gate
    cp99 = (committed.get("serve") or {}).get("p99_ms", 0.0)
    p99_ceiling = max(args.serve_p99_ceiling_ms,
                      cp99 / max(args.check_tolerance, 1e-9))
    gate("serve-p99", svrow["p99_ms"] <= p99_ceiling,
         f"p50={svrow['p50_ms']:.2f}ms p99={svrow['p99_ms']:.2f}ms "
         f"(ceiling {p99_ceiling:.1f}ms = max(floor "
         f"{args.serve_p99_ceiling_ms:.1f}ms, committed {cp99:.2f}ms "
         f"/ tolerance {args.check_tolerance}))")

    gate("weighted-parity", weighted_parity_gate())

    # resilience: the checkpointed streaming fit must be a pure
    # observer (bit-exact vs the plain fit), crash + restore + replay
    # must land on the identical centroids, and the async-save price
    # must stay under 10% + 5ms of the plain streaming wall time.
    # Placed BEFORE streaming-gap so the `failed == ["streaming-gap"]`
    # subsystem exit code below stays precise.
    rrow = resilience_bench.run(scale=scale, epochs=2)
    res_budget_ms = rrow["stream_ms"] * 1.10 + 5.0
    gate("resilience",
         rrow["bit_exact"] and rrow["replay_exact"]
         and rrow["resilient_ms"] <= res_budget_ms,
         f"bit_exact={'OK' if rrow['bit_exact'] else 'FAIL'} "
         f"replay_exact={'OK' if rrow['replay_exact'] else 'FAIL'} "
         f"resilient={rrow['resilient_ms']:.1f}ms "
         f"budget={res_budget_ms:.1f}ms "
         f"(stream={rrow['stream_ms']:.1f}ms * 1.10 + 5ms) "
         f"saves={rrow['ckpt_saves']} replayed={rrow['replayed_batches']}")

    # streaming LAST among the gates so `failed == ["streaming-gap"]`
    # cleanly selects the subsystem-specific exit code
    srow = streaming_bench.run(scale=scale, epochs=3)
    gate("streaming-gap", srow["inertia_gap"] <= 0.05,
         f"inertia_gap={srow['inertia_gap'] * 100:+.2f}% (limit +5%)")

    # perfetto trace artifact: one profiled engine fit, phases annotated
    try:
        import jax
        import jax.numpy as jnp

        from repro.core import engine_fit, kmeans_plusplus
        from repro.data import make_points
        pts_np, _, _ = make_points(4096, 8, 16, seed=0)
        pts = jnp.asarray(pts_np)
        init = kmeans_plusplus(jax.random.PRNGKey(1), pts, 16)
        _, tdir = profile(engine_fit, pts, init, max_iters=10,
                          backend="compact", tune="off",
                          trace_dir="obs_trace", registry=reg)
        print(f"check: perfetto trace -> {tdir}")
    except Exception as e:           # the trace is an artifact, not a gate
        print(f"check: perfetto trace skipped ({e})")

    finish()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller problem sizes (CI-friendly)")
    ap.add_argument("--json", "--out", dest="json",
                    default="BENCH_kmeans.json",
                    help="path for the machine-readable K-means record "
                         "('' disables)")
    ap.add_argument("--check", action="store_true",
                    help="perf regression gate: compare fresh --quick "
                         "results against the committed record; exit 1 "
                         "on regression")
    ap.add_argument("--check-tolerance", type=float, default=0.6,
                    help="--check fails when fresh mean_speedup drops "
                         "below committed * this factor (default 0.6 — "
                         "shared-CI timing noise is large)")
    ap.add_argument("--serve-p99-ceiling-ms", type=float, default=50.0,
                    help="minimum serve-p99 ceiling; the gate uses "
                         "max(this, committed p99 / check-tolerance) "
                         "so loaded runners don't flake on a fresh "
                         "wall-clock percentile")
    ap.add_argument("--tune", action="store_true",
                    help="refresh the engine tuning cache "
                         "(benchmarks/autotune.py) for the suite's "
                         "problem signatures before measuring")
    args = ap.parse_args()
    if args.check:
        check(args)
        return
    scale = 0.1 if args.quick else 1.0

    from . import filter_efficiency, group_sweep, kernel_bench
    from . import (kmeans_speedup, predict_bench, resilience_bench,
                   roofline_report, streaming_bench)

    if args.tune:
        from . import autotune
        print("# === autotune: engine configuration search ===",
              flush=True)
        autotune.main(scale=scale, verbose=False)

    print("# === paper Table: KPynq vs standard K-means ===", flush=True)
    kmeans_speedup.main(scale=scale, json_path=args.json or None)
    print("# === streaming / mini-batch subsystem ===", flush=True)
    streaming_bench.main(scale=scale, json_path=args.json or None)
    print("# === predict path (tiled PassCore assign) ===", flush=True)
    predict_bench.main(scale=scale, json_path=args.json or None)
    print("# === serve path (batched assign, epoch-swapped index) ===",
          flush=True)
    from . import serve_bench
    serve_bench.main(["--scale", str(scale), "--out", args.json or "",
                      "--hist-out", ""])
    print("# === resilience (checkpointed streaming, crash replay) ===",
          flush=True)
    resilience_bench.main(scale=scale, json_path=args.json or None)
    print("# === distributed engine (forced multi-device CPU) ===",
          flush=True)
    # subprocess: the forced device count must be set before jax
    # initialises, which is long done in THIS process
    import os
    import subprocess
    cmd = [sys.executable, "-m", "benchmarks.distributed_bench",
           "--scale", str(scale)] + \
        (["--out", args.json] if args.json else ["--out", ""])
    r = subprocess.run(cmd, env=dict(os.environ))
    if r.returncode:
        print(f"# distributed_bench failed (exit {r.returncode})",
              flush=True)
    print("# === filter efficiency (multi-level filter rates) ===",
          flush=True)
    filter_efficiency.main()
    print("# === kernel microbench + block-skip model ===", flush=True)
    kernel_bench.main()
    print("# === tunable parameters: group-count / K ablation ===",
          flush=True)
    group_sweep.main()
    print("# === roofline table (from dry-run cache) ===", flush=True)
    roofline_report.main()


if __name__ == "__main__":
    main()
