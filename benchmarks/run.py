"""Benchmark harness: one section per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--quick]

Prints ``name,us_per_call,derived`` CSV per line.
"""
import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller problem sizes (CI-friendly)")
    args = ap.parse_args()
    scale = 0.1 if args.quick else 1.0

    from . import filter_efficiency, group_sweep, kernel_bench
    from . import kmeans_speedup, roofline_report

    print("# === paper Table: KPynq vs standard K-means ===", flush=True)
    kmeans_speedup.main(scale=scale)
    print("# === filter efficiency (multi-level filter rates) ===",
          flush=True)
    filter_efficiency.main()
    print("# === kernel microbench + block-skip model ===", flush=True)
    kernel_bench.main()
    print("# === tunable parameters: group-count / K ablation ===",
          flush=True)
    group_sweep.main()
    print("# === roofline table (from dry-run cache) ===", flush=True)
    roofline_report.main()


if __name__ == "__main__":
    main()
