"""Benchmark harness: one section per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--json PATH]

Prints ``name,us_per_call,derived`` CSV per line, and writes the
K-means perf record to ``BENCH_kmeans.json`` (per-dataset ``lloyd_ms``,
``engine_ms``, ``speedup``, ``work_reduction`` + suite means) so the
perf trajectory is tracked across PRs.
"""
import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller problem sizes (CI-friendly)")
    ap.add_argument("--json", default="BENCH_kmeans.json",
                    help="path for the machine-readable K-means record "
                         "('' disables)")
    args = ap.parse_args()
    scale = 0.1 if args.quick else 1.0

    from . import filter_efficiency, group_sweep, kernel_bench
    from . import kmeans_speedup, roofline_report

    print("# === paper Table: KPynq vs standard K-means ===", flush=True)
    kmeans_speedup.main(scale=scale, json_path=args.json or None)
    print("# === filter efficiency (multi-level filter rates) ===",
          flush=True)
    filter_efficiency.main()
    print("# === kernel microbench + block-skip model ===", flush=True)
    kernel_bench.main()
    print("# === tunable parameters: group-count / K ablation ===",
          flush=True)
    group_sweep.main()
    print("# === roofline table (from dry-run cache) ===", flush=True)
    roofline_report.main()


if __name__ == "__main__":
    main()
