"""Predict-path benchmark: tiled PassCore assignment throughput.

``KMeans.predict`` no longer materialises an (N, K) distance matrix —
it runs the engine's tiled candidate pass with cached norms
(``engine.assign``). This module measures its throughput
(points/sec) on the uci-medium shape, checks exact parity with the
dense argmin, and records the row under the ``"predict"`` key of
``BENCH_kmeans.json`` so ``benchmarks/run.py --check`` can smoke it.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.kpynq import paper_suite
from repro.core import engine_fit, kmeans_plusplus
from repro.core import engine as _engine
from repro.data import make_points


def run(scale=1.0, dataset="uci-medium", repeats=5, tile_n=8192):
    prob = next(p for p in paper_suite if p.name == dataset)
    n = max(int(prob.n_points * scale), 2048)
    pts_np, _, _ = make_points(n, prob.n_dims, prob.k, seed=0)
    pts = jnp.asarray(pts_np)
    init = kmeans_plusplus(jax.random.PRNGKey(1), pts, prob.k)
    r = engine_fit(pts, init, n_groups=prob.n_groups, max_iters=20,
                   tol=prob.tol, backend="auto")

    def assign():
        labels, dists = _engine.assign(pts, r.centroids, tile_n=tile_n)
        jax.block_until_ready(labels)
        return labels, dists

    labels, dists = assign()                  # compile + warmup
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        assign()
        best = min(best, time.perf_counter() - t0)

    # exactness: the tiled pass IS the dense argmin. Reference in the
    # SAME f32 norm-cached expression (pairwise_sq_dists) the engine
    # uses, so the gate is structural — an f64 numpy reference would
    # flip on sub-float-tolerance argmin margins and fail CI on a
    # correct assignment.
    from repro.core import pairwise_sq_dists
    ref = np.asarray(jnp.argmin(pairwise_sq_dists(pts, r.centroids),
                                axis=1))
    parity = bool(np.array_equal(np.asarray(labels), ref))
    return {
        "dataset": f"{dataset}-predict", "n": n, "d": prob.n_dims,
        "k": prob.k, "tile_n": tile_n,
        "predict_ms": best * 1e3,
        "points_per_sec": n / best,
        "labels_match_dense": parity,
    }


def write_json(row, path="BENCH_kmeans.json"):
    """Merge the predict record into the shared perf JSON."""
    payload = {}
    if os.path.exists(path):
        with open(path) as fh:
            payload = json.load(fh)
    payload["predict"] = row
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=2)
    return path


def main(argv=None, *, scale=None, json_path=None):
    # CLI args used to be parsed by nobody: ``--scale 0.1 --out ""``
    # silently ran the full-scale bench AND overwrote the committed
    # BENCH row. Parse them for real (keyword args still win so tests
    # and run.py can call main() directly).
    if scale is None and json_path is None:
        ap = argparse.ArgumentParser()
        ap.add_argument("--scale", type=float, default=1.0)
        ap.add_argument("--out", default="BENCH_kmeans.json",
                        help="perf JSON to merge the predict row into "
                             "('' disables)")
        args = ap.parse_args(argv)
        scale, json_path = args.scale, args.out
    elif scale is None:
        scale = 1.0
    row = run(scale=scale)
    print("name,us_per_call,derived")
    print(f"predict/{row['dataset']},{row['predict_ms'] * 1e3:.1f},"
          f"pps={row['points_per_sec']:.0f} tile_n={row['tile_n']} "
          f"parity={'OK' if row['labels_match_dense'] else 'FAIL'}")
    if json_path:
        write_json(row, json_path)
    return row


if __name__ == "__main__":
    main()
