"""Regenerate the §Roofline table inside EXPERIMENTS.md from
results/dryrun/*.json (idempotent: replaces the marker block)."""
from __future__ import annotations

import json
import re
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
RESULTS = ROOT / "results" / "dryrun"
MARKER = "<!-- ROOFLINE_TABLE -->"


def table() -> str:
    rows = []
    for f in sorted(RESULTS.glob("*__16x16.json")):
        r = json.loads(f.read_text())
        if r.get("tag"):
            continue
        if not r.get("ok"):
            rows.append(f"| {r['arch']} | {r['shape']} | FAILED |  |  |  |  |  |")
            continue
        rl = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} "
            f"| {rl['t_compute_s']:.2e} | {rl['t_memory_s']:.2e} "
            f"| {rl['t_collective_s']:.2e} | {rl['bottleneck']} "
            f"| {rl.get('useful_flops_ratio', float('nan')):.3f} "
            f"| {rl.get('roofline_fraction', float('nan')):.5f} |")
    head = ("| arch | shape | compute s | memory s | collective s | "
            "bottleneck | useful | roofline |\n|---|---|---|---|---|---|---|---|")
    return head + "\n" + "\n".join(rows)


def main():
    exp = ROOT / "EXPERIMENTS.md"
    text = exp.read_text()
    block = MARKER + "\n" + table() + "\n"
    if MARKER in text:
        # replace from marker to the next section header
        pattern = re.escape(MARKER) + r".*?(?=\n## |\Z)"
        text = re.sub(pattern, block, text, flags=re.S)
    exp.write_text(text)
    print("EXPERIMENTS.md §Roofline updated "
          f"({len(list(RESULTS.glob('*__16x16.json')))} cells present)")


if __name__ == "__main__":
    main()
