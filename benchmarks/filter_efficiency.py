"""Filter-efficiency figure: per-iteration survival rates of the two
filter levels, and the block-granular density the Pallas kernels see
(the FPGA->TPU adaptation loss: per-point savings vs block savings).

Two block granularities are reported: the (tile_n x tile_k) centroid
blocks of ``filtered_assign`` and the (tile_n x GROUP) blocks of the
engine's ``grouped_assign`` kernel (``gblock*`` columns) — the latter
maps each group-filter decision onto exactly one skippable block, so
its density is the fraction of MXU work the engine's TPU backend
actually issues. ``gbucket`` is the max surviving-group count per
candidate: the engine's centroid-level compaction gathers only this
many group buckets per point on CPU/GPU."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import kmeans_plusplus
from repro.core.distances import pairwise_dists, rowwise_dists
from repro.core.kmeans import (_filtered_step, _init_filter_state,
                               group_centroids)
from repro.data import make_points
from repro.kernels import build_block_mask, build_group_block_mask


def run(n=32768, d=32, k=128, iters=12,
        tiles=((256, 128), (64, 16), (64, 8))):
    pts_np, _, _ = make_points(n, d, k, seed=0)
    pts = jnp.asarray(pts_np)
    init = kmeans_plusplus(jax.random.PRNGKey(1), pts, k)
    g = max(k // 10, 1)
    groups = group_centroids(init, g)
    state = _init_filter_state(pts, init, groups, g)
    rows = []
    for it in range(iters):
        # recompute the filter decisions exactly as _filtered_step does
        new_c = state.centroids  # bounds already reflect last move
        prev = state
        state = _filtered_step(pts, state, groups, g, k)
        # reconstruct rates from the counters
        drift = jnp.linalg.norm(state.centroids - prev.centroids, axis=-1)
        ub = prev.ub + drift[prev.assignments]
        gd = jax.ops.segment_max(drift, groups, num_segments=g)
        lb = jnp.maximum(prev.lb - gd[None, :], 0.0)
        glb = jnp.min(lb, axis=1)
        maybe = ub > glb
        d_own = rowwise_dists(pts, state.centroids[prev.assignments])
        ub_t = jnp.where(maybe, d_own, ub)
        need = ub_t > glb
        group_need = need[:, None] & (lb < ub_t[:, None])
        gcnt = jnp.sum(group_need.astype(jnp.int32), axis=1)
        row = {"iter": it,
               "point_survival": float(jnp.mean(need)),
               "pair_survival": float(jnp.mean(group_need[:, groups])),
               "gbucket": int(jnp.max(gcnt))}
        # block density at several tile granularities, unsorted and with
        # points re-ordered by current assignment (colocates survivors —
        # the data-layout half of the FPGA->TPU co-design)
        order = jnp.argsort(state.assignments)
        gn_sorted = group_need[order]
        for tn, tk in tiles:
            m = build_block_mask(group_need, groups, tile_n=tn, tile_k=tk)
            ms = build_block_mask(gn_sorted, groups, tile_n=tn, tile_k=tk)
            row[f"block{tn}x{tk}"] = float(jnp.mean(m))
            row[f"block{tn}x{tk}_sorted"] = float(jnp.mean(ms))
        for tn in sorted({t for t, _ in tiles}):
            gm = build_group_block_mask(group_need, tile_n=tn)
            gms = build_group_block_mask(gn_sorted, tile_n=tn)
            row[f"gblock{tn}"] = float(jnp.mean(gm))
            row[f"gblock{tn}_sorted"] = float(jnp.mean(gms))
        rows.append(row)
    return rows


def main():
    rows = run()
    print("name,us_per_call,derived")
    for r in rows:
        extras = " ".join(f"{k.replace('block', 'b')}={v:.3f}"
                          for k, v in r.items()
                          if "block" in k)
        print(f"filter_efficiency/iter{r['iter']:02d},,"
              f"point={r['point_survival']:.3f} "
              f"pair={r['pair_survival']:.3f} "
              f"gbucket={r['gbucket']} {extras}")
    last = rows[-1]
    extras = " ".join(f"{k.replace('block', 'b')}={v:.3f}"
                      for k, v in last.items() if "block" in k)
    print(f"filter_efficiency/STEADY,,point={last['point_survival']:.3f} "
          f"pair={last['pair_survival']:.3f} "
          f"gbucket={last['gbucket']} {extras}")
    return rows


if __name__ == "__main__":
    main()
