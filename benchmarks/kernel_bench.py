"""Kernel microbench.

interpret=True timings are meaningless (Python emulation), so this
benchmark reports (a) XLA-path wall time of the same math — the oracle
the kernels were validated against — and (b) the ANALYTIC effect of
block-skip on the Pallas kernel: MXU FLOPs and HBM bytes at measured
block densities vs the dense kernel, from the BlockSpec tiling model:

  per live block: tile_n*tile_k*(2*D) MXU flops,
                  (tile_n*D + tile_k*D + tile_n*tile_k)*dtype bytes
  skipped block:  1 SMEM scalar read.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels.ref import centroid_update_ref, pairwise_sq_dists_ref


def _time(fn, *args, repeats=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeats


def block_skip_model(n, d, k, density, tile_n=256, tile_k=128,
                     dtype_bytes=4):
    gn, gk = -(-n // tile_n), -(-k // tile_k)
    live = gn * gk * density
    flops_dense = gn * gk * (tile_n * tile_k * 2 * d)
    flops_skip = live * (tile_n * tile_k * 2 * d)
    bytes_dense = gn * gk * (tile_n * d + tile_k * d +
                             tile_n * tile_k) * dtype_bytes
    bytes_skip = live * (tile_n * d + tile_k * d +
                         tile_n * tile_k) * dtype_bytes
    return {"flops_saving": flops_dense / max(flops_skip, 1),
            "bytes_saving": bytes_dense / max(bytes_skip, 1)}


def main():
    print("name,us_per_call,derived")
    key = jax.random.PRNGKey(0)
    for (n, d, k) in [(32768, 32, 128), (131072, 64, 256)]:
        x = jax.random.normal(key, (n, d))
        c = jax.random.normal(key, (k, d))
        f = jax.jit(pairwise_sq_dists_ref)
        t = _time(f, x, c)
        gflops = 2 * n * d * k / t / 1e9
        print(f"kernel/pairwise_dist_{n}x{d}x{k},{t * 1e6:.0f},"
              f"xla_cpu={gflops:.1f}GFLOP/s")
        a = jax.random.randint(key, (n,), 0, k)
        g = jax.jit(lambda xx, aa: centroid_update_ref(xx, aa, k))
        t = _time(g, x, a)
        print(f"kernel/centroid_update_{n}x{d}x{k},{t * 1e6:.0f},"
              f"xla_cpu_onehot_matmul")
    # analytic block-skip savings at the measured steady-state density
    for density in (0.1, 0.25, 0.5):
        m = block_skip_model(131072, 64, 256, density)
        print(f"kernel/block_skip_model_density{density},,"
              f"flops_saving={m['flops_saving']:.1f}x "
              f"bytes_saving={m['bytes_saving']:.1f}x")


if __name__ == "__main__":
    main()
