"""Resilience benchmark: checkpointed streaming fit vs plain fit.

Three measurements on the uci-medium streaming config:

* ``stream_ms``    — plain ``fit_stream`` wall time (no checkpoints);
* ``resilient_ms`` — the same fit under ``resilient=True`` with async
  checkpoints every ``ckpt_every`` batches (plus the terminal sync
  save): the price of crash-safety;
* ``replay_exact`` — an injected mid-epoch failure, restore from the
  newest async checkpoint, deterministic replay of the ``(seed,
  shard)`` stream — final centroids must be bit-identical to the
  uninterrupted fit.

``bit_exact`` asserts the failure-free checkpointed fit equals the
plain fit bitwise (checkpointing must be a pure observer), and the
``benchmarks/run.py --check`` resilience gate additionally bounds
``resilient_ms <= stream_ms * 1.10 + 5ms``.

Merged into BENCH_kmeans.json under the ``"resilience"`` key.
"""
from __future__ import annotations

import json
import os
import tempfile
import time

import numpy as np

from repro.configs.kpynq import paper_suite
from repro.data import PointStream, make_points
from repro.runtime import FailureInjector
from repro.streaming import StreamingKMeans


def run(scale=1.0, epochs=2, shard_size=2048, dataset="uci-medium",
        ckpt_every=4, repeats=2):
    prob = next(p for p in paper_suite if p.name == dataset)
    n = max(int(prob.n_points * scale), 2048)
    pts_np, _, _ = make_points(n, prob.n_dims, prob.k, seed=0)
    stream = PointStream(shard_size=min(shard_size, n), data=pts_np)

    def fresh():
        return StreamingKMeans(prob.k, n_groups=prob.n_groups, seed=1,
                               init_size=min(2 * shard_size, n))

    # warmup: compile every kernel once so neither timed mode pays JIT
    fresh().fit_stream(stream, epochs=1)

    # plain vs checkpointed, best-of-``repeats`` with a fresh estimator
    # per repetition (a streaming fit mutates its estimator, so reruns
    # on the same object would measure the warm-cache epoch instead)
    t_plain = float("inf")
    for _ in range(repeats):
        skm_plain = fresh()
        t0 = time.perf_counter()
        skm_plain.fit_stream(stream, epochs=epochs)
        t_plain = min(t_plain, time.perf_counter() - t0)

    t_ck = float("inf")
    for _ in range(repeats):
        with tempfile.TemporaryDirectory() as d:
            skm_ck = fresh()
            t0 = time.perf_counter()
            skm_ck.fit_stream(stream, epochs=epochs, resilient=True,
                              ckpt_dir=d, ckpt_every=ckpt_every)
            t_ck = min(t_ck, time.perf_counter() - t0)

    bit_exact = (np.array_equal(np.asarray(skm_plain.cluster_centers_),
                                np.asarray(skm_ck.cluster_centers_))
                 and np.array_equal(np.asarray(skm_plain.counts_),
                                    np.asarray(skm_ck.counts_)))

    # chaos row: crash mid-epoch (off the checkpoint lattice so the
    # replay path actually runs), restore + replay, compare bitwise
    n_steps = max(epochs, 1) * len(stream)
    fail_at = max(1, n_steps // 2)
    if fail_at % ckpt_every == 0:
        fail_at += 1
    with tempfile.TemporaryDirectory() as d:
        skm_ch = fresh()
        skm_ch.fit_stream(stream, epochs=epochs, resilient=True,
                          ckpt_dir=d, ckpt_every=ckpt_every,
                          injector=FailureInjector(fail_at=(fail_at,)))
    st = skm_ch.stats_
    replay_exact = (st.restores >= 1
                    and np.array_equal(np.asarray(skm_plain.cluster_centers_),
                                       np.asarray(skm_ch.cluster_centers_))
                    and np.array_equal(np.asarray(skm_plain.counts_),
                                       np.asarray(skm_ch.counts_)))

    return {
        "dataset": f"{dataset}-resilient", "n": n, "d": prob.n_dims,
        "k": prob.k, "shard_size": stream.shard_size, "epochs": epochs,
        "batches": n_steps, "ckpt_every": ckpt_every,
        "stream_ms": t_plain * 1e3,
        "resilient_ms": t_ck * 1e3,
        "save_overhead_pct": (t_ck / max(t_plain, 1e-12) - 1.0) * 100.0,
        "ckpt_saves": skm_ck.stats_.ckpt_saves,
        "bit_exact": bool(bit_exact),
        "fail_at": fail_at,
        "restores": st.restores,
        "replayed_batches": st.replayed_batches,
        "replay_exact": bool(replay_exact),
    }


def write_json(row, path="BENCH_kmeans.json"):
    """Merge the resilience record into the shared perf JSON."""
    payload = {}
    if os.path.exists(path):
        with open(path) as fh:
            payload = json.load(fh)
    payload["resilience"] = row
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=2)
    return path


def main(scale=1.0, epochs=2, json_path=None):
    row = run(scale=scale, epochs=epochs)
    print("name,us_per_call,derived")
    print(f"resilience/{row['dataset']},{row['resilient_ms'] * 1e3:.1f},"
          f"stream_ms={row['stream_ms']:.1f} "
          f"overhead={row['save_overhead_pct']:+.1f}% "
          f"saves={row['ckpt_saves']} "
          f"bit_exact={'OK' if row['bit_exact'] else 'FAIL'} "
          f"replay_exact={'OK' if row['replay_exact'] else 'FAIL'} "
          f"restores={row['restores']} replayed={row['replayed_batches']}")
    if json_path:
        write_json(row, json_path)
    return row


if __name__ == "__main__":
    main(json_path="BENCH_kmeans.json")
