"""Streaming mini-batch K-means: throughput + inertia gap vs batch fit.

The ROADMAP north-star workload: points arrive as shards, the fit never
holds the dataset at once. Reports, on the uci-medium config:

* ``cold_pps`` — points/sec of the first pass (cache-miss path: every
  batch pays the full candidate pass + JIT warmup);
* ``warm_pps`` — points/sec of subsequent epochs, where the per-shard
  carried bounds (drift-inflated across batches) skip most work;
* ``inertia_gap`` — final-inertia-vs-full-batch-engine gap (the
  acceptance metric: must stay within 5%);
* work/cache diagnostics from ``StreamStats``.

Merged into BENCH_kmeans.json under the ``"streaming"`` key so the
``benchmarks/run.py --check`` gate covers the subsystem.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp

from repro.configs.kpynq import paper_suite
from repro.core import engine_fit, kmeans_plusplus
from repro.data import PointStream, make_points
from repro.streaming import StreamingKMeans


def run(scale=1.0, epochs=3, shard_size=2048, dataset="uci-medium"):
    prob = next(p for p in paper_suite if p.name == dataset)
    n = max(int(prob.n_points * scale), 2048)
    pts_np, _, _ = make_points(n, prob.n_dims, prob.k, seed=0)
    pts = jnp.asarray(pts_np)
    init = kmeans_plusplus(jax.random.PRNGKey(1), pts, prob.k)

    t0 = time.perf_counter()
    r_b = engine_fit(pts, init, n_groups=prob.n_groups,
                     max_iters=prob.max_iters, tol=prob.tol, backend="auto")
    jax.block_until_ready(r_b.centroids)
    t_batch = time.perf_counter() - t0

    stream = PointStream(shard_size=min(shard_size, n), data=pts_np)
    skm = StreamingKMeans(prob.k, n_groups=prob.n_groups, seed=1,
                          init_size=min(2 * shard_size, n))
    t0 = time.perf_counter()
    skm.fit_stream(stream, epochs=1)
    t_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    if epochs > 1:
        skm.fit_stream(stream, epochs=epochs - 1)
    t_warm = max(time.perf_counter() - t0, 1e-9)

    inertia_stream = skm.inertia_of(pts_np)
    st = skm.stats_
    return {
        "dataset": f"{dataset}-stream", "n": n, "d": prob.n_dims,
        "k": prob.k, "shard_size": stream.shard_size, "epochs": epochs,
        "batches": st.batches,
        "cold_pps": n / t_cold,
        "warm_pps": (max(epochs - 1, 0) * n) / t_warm if epochs > 1
        else n / t_cold,
        "batch_ms": t_batch * 1e3,
        "stream_ms": (t_cold + (t_warm if epochs > 1 else 0.0)) * 1e3,
        "inertia_batch": float(r_b.inertia),
        "inertia_stream": inertia_stream,
        "inertia_gap": inertia_stream / max(float(r_b.inertia), 1e-12) - 1.0,
        "distance_evals": st.distance_evals,
        "dense_equiv_evals": float(st.points_seen) * prob.k,
        "cache_hits": st.cache_hits, "cache_misses": st.cache_misses,
        "drift_resets": st.drift_resets, "reseeds": st.reseeds,
    }


def write_json(row, path="BENCH_kmeans.json"):
    """Merge the streaming record into the shared perf JSON."""
    payload = {}
    if os.path.exists(path):
        with open(path) as fh:
            payload = json.load(fh)
    payload["streaming"] = row
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=2)
    return path


def main(scale=1.0, epochs=3, json_path=None):
    row = run(scale=scale, epochs=epochs)
    print("name,us_per_call,derived")
    print(f"streaming/{row['dataset']},{row['stream_ms'] * 1e3:.1f},"
          f"warm_pps={row['warm_pps']:.0f} cold_pps={row['cold_pps']:.0f} "
          f"inertia_gap={row['inertia_gap'] * 100:+.2f}% "
          f"work_red={row['dense_equiv_evals'] / max(row['distance_evals'], 1):.2f}x "
          f"hits={row['cache_hits']}/{row['batches']} "
          f"resets={row['drift_resets']} reseeds={row['reseeds']}")
    if json_path:
        write_json(row, json_path)
    return row


if __name__ == "__main__":
    main(json_path="BENCH_kmeans.json")
