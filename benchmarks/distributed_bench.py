"""Distributed engine benchmark: sharded compact vs sharded dense.

Runs the uci-medium-class shape through ``distributed_yinyang`` on a
multi-device mesh — on CPU boxes the devices are forced with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (set below
BEFORE jax initialises, so this module must be the process entrypoint:
``python -m benchmarks.distributed_bench``; ``benchmarks/run.py``
spawns it as a subprocess for exactly that reason).

Reports, and records under the ``"distributed"`` key of
``BENCH_kmeans.json``:

* ``dense_ms`` / ``compact_ms`` — wall-clock of the legacy masked-dense
  per-shard pass vs the capacity-bucketed compaction inside the
  ``shard_map`` body (the PR 4 tentpole);
* ``work_reduction`` — psum'd ``distance_evals`` vs the dense
  equivalent (N*K per iteration + the init pass): the per-shard filter
  work saving surviving distribution (must stay > 1.0 — CI gates on
  the committed value via ``benchmarks/run.py --check``);
* ``assignments_match`` — sharded-compact vs sharded-dense parity
  (bit-identical by construction: same psum reduction order);
* ``inertia_rel_err`` — vs the single-device engine fixed point.

``--check`` exits non-zero when parity fails or the measured work
reduction is <= 1.0 — the multi-device CI lane runs it directly.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

_FORCE = "--xla_force_host_platform_device_count"
if __name__ == "__main__" and _FORCE not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + f" {_FORCE}=4").strip()

import jax              # noqa: E402  (after the device-count env var)
import jax.numpy as jnp  # noqa: E402
import numpy as np       # noqa: E402

from repro.configs.kpynq import paper_suite               # noqa: E402
from repro.core import (distributed_yinyang, engine_fit,  # noqa: E402
                        kmeans_plusplus)
from repro.data import make_points                        # noqa: E402


def _time_best(fn, repeats=3):
    out = fn()                          # compile + warm caches
    jax.block_until_ready(out.centroids)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        r = fn()
        jax.block_until_ready(r.centroids)
        best = min(best, time.perf_counter() - t0)
    return out, best


def run(scale=1.0, dataset="uci-medium", repeats=3):
    prob = next(p for p in paper_suite if p.name == dataset)
    n = max(int(prob.n_points * scale), 2048)
    n_dev = jax.device_count()
    pts_np, _, _ = make_points(n, prob.n_dims, prob.k, seed=0)
    pts = jnp.asarray(pts_np)
    init = kmeans_plusplus(jax.random.PRNGKey(1), pts, prob.k)
    mesh = jax.make_mesh((n_dev,), ("data",))

    kw = dict(n_groups=prob.n_groups, max_iters=prob.max_iters,
              tol=prob.tol)
    r_dense, t_dense = _time_best(
        lambda: distributed_yinyang(pts, init, mesh, backend="dense",
                                    **kw), repeats)
    r_comp, t_comp = _time_best(
        lambda: distributed_yinyang(pts, init, mesh, backend="compact",
                                    **kw), repeats)
    r_single = engine_fit(pts, init, backend="compact", tune="off", **kw)
    # telemetry pass OUTSIDE the timed loops: per-shard rings + skew
    # (results are bit-identical, so the rings describe the timed fit)
    _, dstats = distributed_yinyang(pts, init, mesh, backend="compact",
                                    return_stats=True, **kw)

    iters = int(r_comp.n_iters)
    # dense equivalent: the init pass + one full (N, K) pass per
    # iteration plus the epilogue — same convention as the single-
    # device rows (Lloyd's counter)
    dense_equiv = float(n) * prob.k * (iters + 1)
    evals = float(r_comp.distance_evals)
    inertia_s = float(r_single.inertia)
    return {
        "dataset": f"{dataset}-dist", "n": n, "d": prob.n_dims,
        "k": prob.k, "devices": n_dev, "iters": iters,
        "dense_ms": t_dense * 1e3, "compact_ms": t_comp * 1e3,
        "speedup_vs_dense": t_dense / t_comp,
        "distance_evals": evals,
        "dense_equiv_evals": dense_equiv,
        "work_reduction": dense_equiv / max(evals, 1.0),
        "assignments_match": bool(np.array_equal(
            np.asarray(r_dense.assignments),
            np.asarray(r_comp.assignments))),
        "inertia": float(r_comp.inertia),
        "inertia_rel_err": abs(float(r_comp.inertia) - inertia_s)
        / max(inertia_s, 1e-12),
        # ring summary incl. per-shard work skew (max/mean evals per
        # iteration across shards; 1.0 = perfectly balanced)
        "telemetry": dstats.telemetry(),
    }


def write_json(row, path="BENCH_kmeans.json"):
    """Merge the distributed record into the shared perf JSON."""
    payload = {}
    if os.path.exists(path):
        with open(path) as fh:
            payload = json.load(fh)
    payload["distributed"] = row
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=2)
    return path


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--dataset", default="uci-medium")
    ap.add_argument("--json", "--out", dest="json",
                    default="BENCH_kmeans.json",
                    help="perf record to merge into ('' disables)")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero when compact/dense parity fails "
                         "or work_reduction <= 1.0 (CI gate)")
    args = ap.parse_args(argv)
    if jax.device_count() < 2:
        print("distributed_bench: single device — run as "
              f"`python -m benchmarks.distributed_bench` (or set "
              f"XLA_FLAGS={_FORCE}=4)", file=sys.stderr)
        sys.exit(2)

    row = run(scale=args.scale, dataset=args.dataset)
    print("name,us_per_call,derived")
    print(f"distributed/{row['dataset']},{row['compact_ms'] * 1e3:.1f},"
          f"devices={row['devices']} "
          f"vs_dense={row['speedup_vs_dense']:.2f}x "
          f"work_red={row['work_reduction']:.2f}x "
          f"parity={'OK' if row['assignments_match'] else 'FAIL'} "
          f"inertia_err={row['inertia_rel_err']:.2e} "
          f"iters={row['iters']} "
          f"skew={(row['telemetry'] or {}).get('max_shard_skew', 1.0):.2f}")
    if args.json:
        write_json(row, args.json)
    if args.check:
        ok = row["assignments_match"] and row["work_reduction"] > 1.0 \
            and row["inertia_rel_err"] < 1e-3
        print(f"check: distributed parity+work gate -> "
              f"{'OK' if ok else 'REGRESSION'}")
        sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
