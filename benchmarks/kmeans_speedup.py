"""Paper Table analogue: filtered vs standard K-means across the
UCI-like suite — wall time, speedup, distance-evaluation reduction.

The paper reports 2.95x mean speedup (max 4.2x) for the FPGA pipeline
vs an optimized CPU Lloyd. Here every algorithm runs on the SAME device
(this container's CPU via XLA), so the speedup isolates the paper's
*algorithmic* contribution (the multi-level filter); the hardware
pipeline contribution shows up in §Roofline instead.

Three filtered execution modes are reported side by side:

* ``oracle``  — masked-dense ``yinyang`` (every distance computed,
  filtered ones discarded): the exactness reference, no wall-clock win.
* ``compact`` — the legacy host-driven compaction driver
  (``yinyang_compact``): per-iteration host syncs + recompiles.
* ``engine``  — the device-resident engine (``repro.core.engine``,
  ``backend='auto'``, ``tune='auto'``): the product path. ``speedup``
  / ``kpynq_ms`` in the emitted rows refer to THIS mode. When the
  tuning cache has an entry for the problem's (platform, N, K, D)
  signature (``benchmarks/run.py --tune`` refreshes it), the engine
  runs the tuned configuration and the row records it under
  ``tuned``.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp

from repro import tune as _tune
from repro.configs.kpynq import paper_suite
from repro.core import (engine_fit, kmeans_plusplus, lloyd, yinyang,
                        yinyang_compact)
from repro.data import make_points
from repro.obs import ObsConfig, provenance


def _time_interleaved(fns, repeats=4, min_seconds=0.8, max_repeats=16):
    """Best-of-N wall-clock for each thunk, with the timed repetitions
    INTERLEAVED across modes (l, o, c, e, l, o, c, e, ...) rather than
    phase-by-phase: ambient machine drift (frequency scaling,
    co-tenants) then hits every mode equally instead of biasing
    whichever ran in the slow window — at the per-row gate margins of
    ISSUE 3 that bias exceeded the engine-vs-Lloyd gap. Short rows
    keep sampling (up to ``max_repeats`` rounds) until ``min_seconds``
    of timing has accumulated, so their best-of really is the floor."""
    outs = []
    for fn in fns:                        # warmup: compile + caches
        out = fn()
        jax.block_until_ready(out.centroids)
        outs.append(out)
    best = [float("inf")] * len(fns)
    done, spent = 0, 0.0
    while done < repeats or (spent < min_seconds and done < max_repeats):
        for j, fn in enumerate(fns):
            t0 = time.perf_counter()
            out = fn()
            jax.block_until_ready(out.centroids)
            dt = time.perf_counter() - t0
            best[j] = min(best[j], dt)
            spent += dt
        done += 1
    return outs, best


def run(limit=None, scale=1.0):
    rows = []
    suite = paper_suite[:limit]
    for prob in suite:
        n = max(int(prob.n_points * scale), 512)
        pts_np, _, _ = make_points(n, prob.n_dims, prob.k, seed=0)
        pts = jnp.asarray(pts_np)
        init = kmeans_plusplus(jax.random.PRNGKey(1), pts, prob.k)
        jit_lloyd = jax.jit(lambda p, i: lloyd(p, i, prob.max_iters,
                                               prob.tol))
        jit_oracle = jax.jit(lambda p, i: yinyang(
            p, i, prob.n_groups, prob.max_iters, prob.tol))
        (r_l, r_o, r_c, r_e), (t_l, t_o, t_c, t_e) = _time_interleaved([
            lambda: jit_lloyd(pts, init),
            lambda: jit_oracle(pts, init),
            lambda: yinyang_compact(pts, init, prob.n_groups,
                                    prob.max_iters, prob.tol),
            lambda: engine_fit(pts, init, n_groups=prob.n_groups,
                               max_iters=prob.max_iters, tol=prob.tol,
                               backend="auto"),
        ])
        entry = _tune.default_cache().entry(
            _tune.signature(n, prob.k, prob.n_dims))
        # telemetry row: one extra obs-enabled fit OUTSIDE the timed
        # loops (the ring drain costs a device_get the timed rows must
        # not pay) — results are bit-identical, so the ring describes
        # exactly the fit that was measured above
        _, st = engine_fit(pts, init, n_groups=prob.n_groups,
                           max_iters=prob.max_iters, tol=prob.tol,
                           backend="auto", obs=ObsConfig(),
                           return_stats=True)
        rows.append({
            "dataset": prob.name, "n": n, "d": prob.n_dims, "k": prob.k,
            "iters": int(r_l.n_iters),
            "lloyd_ms": t_l * 1e3, "oracle_ms": t_o * 1e3,
            "compact_ms": t_c * 1e3, "engine_ms": t_e * 1e3,
            "kpynq_ms": t_e * 1e3,
            "speedup": t_l / t_e,
            "speedup_oracle": t_l / t_o,
            "speedup_compact": t_l / t_c,
            "evals_lloyd": float(r_l.distance_evals),
            "evals_kpynq": float(r_e.distance_evals),
            "work_reduction": float(r_l.distance_evals) /
            max(float(r_e.distance_evals), 1.0),
            # the winning engine configuration this row was measured
            # under (None = untuned defaults)
            "tuned": (entry or {}).get("config"),
            # per-iteration ring summary: iters-to-converge, mean
            # candidate fraction surviving the filters, total evals
            "telemetry": st.telemetry(),
        })
    return rows


def summarize(rows):
    sp = [r["speedup"] for r in rows]
    sp_c = [r["speedup_compact"] for r in rows]
    wr = [r["work_reduction"] for r in rows]
    return {
        "mean_speedup": sum(sp) / len(sp),
        "max_speedup": max(sp),
        "mean_speedup_compact": sum(sp_c) / len(sp_c),
        "mean_work_reduction": sum(wr) / len(wr),
    }


def write_json(rows, path="BENCH_kmeans.json", scale=1.0):
    """Machine-readable perf record so the trajectory is tracked
    across PRs (consumed by CI via ``benchmarks/run.py --check`` and by
    later sessions). Preserves the ``streaming`` / ``distributed`` /
    ``predict`` / ``resilience`` sections owned by
    ``streaming_bench.py`` / ``distributed_bench.py`` /
    ``predict_bench.py`` / ``resilience_bench.py``.
    ``scale`` is recorded so the --check gate can re-measure at the
    SAME problem sizes (speedups at different n are incommensurable:
    tiny problems auto-route to Lloyd)."""
    payload = {}
    try:
        with open(path) as fh:
            payload = {k: v for k, v in json.load(fh).items()
                       if k in ("streaming", "distributed", "predict",
                                "resilience")}
    except (FileNotFoundError, ValueError):
        pass
    payload["scale"] = scale
    payload["provenance"] = provenance()
    payload["datasets"] = [
        {key: r[key] for key in ("dataset", "n", "d", "k", "iters",
                                 "lloyd_ms", "oracle_ms", "compact_ms",
                                 "engine_ms", "speedup", "work_reduction",
                                 "tuned", "telemetry")}
        for r in rows]
    payload.update(summarize(rows))
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=2)
    return path


def main(scale=1.0, limit=None, json_path=None):
    rows = run(limit=limit, scale=scale)
    print("name,us_per_call,derived")
    for r in rows:
        print(f"kmeans_speedup/{r['dataset']},{r['engine_ms'] * 1e3:.1f},"
              f"speedup={r['speedup']:.2f}x "
              f"compact={r['speedup_compact']:.2f}x "
              f"oracle={r['speedup_oracle']:.2f}x "
              f"work_red={r['work_reduction']:.2f}x iters={r['iters']}")
    s = summarize(rows)
    print(f"kmeans_speedup/MEAN,,speedup={s['mean_speedup']:.2f}x "
          f"max={s['max_speedup']:.2f}x "
          f"compact_mean={s['mean_speedup_compact']:.2f}x "
          f"work_red_mean={s['mean_work_reduction']:.2f}x")
    if json_path:
        write_json(rows, json_path, scale=scale)
    return rows


if __name__ == "__main__":
    main(json_path="BENCH_kmeans.json")
