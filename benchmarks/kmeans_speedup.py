"""Paper Table analogue: filtered vs standard K-means across the
UCI-like suite — wall time, speedup, distance-evaluation reduction.

The paper reports 2.95x mean speedup (max 4.2x) for the FPGA pipeline
vs an optimized CPU Lloyd. Here both algorithms run on the SAME device
(this container's CPU via XLA), so the speedup isolates the paper's
*algorithmic* contribution (the multi-level filter); the hardware
pipeline contribution shows up in §Roofline instead.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs.kpynq import paper_suite
from repro.core import kmeans_plusplus, lloyd, yinyang, yinyang_compact
from repro.data import make_points


def _time(fn, *args, repeats=1, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out.centroids)
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kw)
        jax.block_until_ready(out.centroids)
    return out, (time.perf_counter() - t0) / repeats


def run(limit=None, scale=1.0):
    rows = []
    suite = paper_suite[:limit]
    for prob in suite:
        n = max(int(prob.n_points * scale), 512)
        pts_np, _, _ = make_points(n, prob.n_dims, prob.k, seed=0)
        pts = jnp.asarray(pts_np)
        init = kmeans_plusplus(jax.random.PRNGKey(1), pts, prob.k)
        jit_lloyd = jax.jit(lambda p, i: lloyd(p, i, prob.max_iters,
                                               prob.tol))
        r_l, t_l = _time(jit_lloyd, pts, init)
        # wall-clock: the compaction execution mode (actually skips work
        # on CPU; the Pallas block-skip kernel is the TPU analogue)
        r_y, t_y = _time(lambda p, i: yinyang_compact(
            p, i, prob.n_groups, prob.max_iters, prob.tol), pts, init)
        rows.append({
            "dataset": prob.name, "n": n, "d": prob.n_dims, "k": prob.k,
            "iters": int(r_l.n_iters),
            "lloyd_ms": t_l * 1e3, "kpynq_ms": t_y * 1e3,
            "speedup": t_l / t_y,
            "evals_lloyd": float(r_l.distance_evals),
            "evals_kpynq": float(r_y.distance_evals),
            "work_reduction": float(r_l.distance_evals /
                                    max(r_y.distance_evals, 1.0)),
        })
    return rows


def main(scale=1.0, limit=None):
    rows = run(limit=limit, scale=scale)
    print("name,us_per_call,derived")
    for r in rows:
        print(f"kmeans_speedup/{r['dataset']},{r['kpynq_ms'] * 1e3:.1f},"
              f"speedup={r['speedup']:.2f}x work_red="
              f"{r['work_reduction']:.2f}x iters={r['iters']}")
    sp = [r["speedup"] for r in rows]
    wr = [r["work_reduction"] for r in rows]
    print(f"kmeans_speedup/MEAN,,speedup={sum(sp) / len(sp):.2f}x "
          f"max={max(sp):.2f}x work_red_mean={sum(wr) / len(wr):.2f}x")
    return rows


if __name__ == "__main__":
    main()
