"""Refresh the engine's tuning cache for the benchmark suite.

  PYTHONPATH=src python -m benchmarks.autotune [--scale 0.1] [--limit N]

For each problem of the paper suite (at the given scale), runs the
measured configuration search (:func:`repro.tune.autotune`) and
persists the winner under its (platform, N, K, D) signature — after
which every ``engine.fit(tune="auto")`` on a same-signature problem
(including ``benchmarks.kmeans_speedup``) picks the tuned config up
automatically. Invoked by ``benchmarks/run.py --tune``.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro import tune as _tune
from repro.configs.kpynq import paper_suite
from repro.core import kmeans_plusplus
from repro.data import make_points


def tune_suite(scale=1.0, limit=None, repeats=3, max_measurements=32,
               verbose=False):
    """Autotune every suite problem; returns [(name, signature,
    EngineConfig, cache_entry)] in suite order."""
    rows = []
    cache = _tune.default_cache()
    for prob in paper_suite[:limit]:
        n = max(int(prob.n_points * scale), 512)
        pts_np, _, _ = make_points(n, prob.n_dims, prob.k, seed=0)
        pts = jnp.asarray(pts_np)
        init = kmeans_plusplus(jax.random.PRNGKey(1), pts, prob.k)
        cfg = _tune.autotune(
            pts, init, n_groups=prob.n_groups, max_iters=prob.max_iters,
            tol=prob.tol, cache=cache, repeats=repeats,
            max_measurements=max_measurements, verbose=verbose)
        sig = _tune.signature(n, prob.k, prob.n_dims)
        rows.append((prob.name, sig, cfg, cache.entry(sig)))
    return rows


def main(scale=1.0, limit=None, verbose=True):
    rows = tune_suite(scale=scale, limit=limit, verbose=verbose)
    print("name,us_per_call,derived")
    for name, sig, cfg, entry in rows:
        ms = (entry or {}).get("ms", float("nan"))
        lms = (entry or {}).get("lloyd_ms", float("nan"))
        print(f"autotune/{name},{ms * 1e3:.1f},backend={cfg.backend} "
              f"min_cap={cfg.min_cap} chunk={cfg.chunk} "
              f"ggf={cfg.group_gather_factor} down=({cfg.down_n},"
              f"{cfg.down_g}) tile_n={cfg.tile_n} "
              f"lloyd_ms={lms:.2f} sig={sig}")
    print(f"autotune/CACHE,,path={_tune.default_cache().path}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--limit", type=int, default=None)
    ap.add_argument("--quiet", action="store_true")
    a = ap.parse_args()
    main(scale=a.scale, limit=a.limit, verbose=not a.quiet)
