"""Data pipelines."""
from .pipeline import PrefetchingLoader, TokenPipeline, make_points

__all__ = ["TokenPipeline", "PrefetchingLoader", "make_points"]
