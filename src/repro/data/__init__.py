"""Data pipelines."""
from .pipeline import (PointStream, PrefetchingLoader, TokenPipeline,
                       make_points)

__all__ = ["TokenPipeline", "PrefetchingLoader", "PointStream",
           "make_points"]
