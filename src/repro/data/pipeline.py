"""Data pipelines: sharded synthetic + file-backed token streams, and
point-cloud generators for the K-means workloads.

Design: the host produces GLOBAL batches deterministically from
(seed, step) — so any host can regenerate any step's batch, which is
what makes restart-from-checkpoint and elastic re-sharding trivial (no
data-loader state to persist beyond the step counter). A background
prefetch thread keeps ``depth`` batches ahead of the training loop
(compute/host-IO overlap).
"""
from __future__ import annotations

import queue
import threading

import jax
import numpy as np


class TokenPipeline:
    """Deterministic synthetic LM batches (or memory-mapped corpus)."""

    def __init__(self, cfg, batch: int, seq: int, seed: int = 0,
                 corpus: np.ndarray | None = None):
        self.cfg = cfg
        self.batch = batch
        self.seq = seq
        self.seed = seed
        self.corpus = corpus

    def global_batch(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        if self.corpus is not None:
            starts = rng.integers(0, len(self.corpus) - self.seq - 1,
                                  size=self.batch)
            toks = np.stack([self.corpus[s:s + self.seq + 1]
                             for s in starts])
        else:
            toks = rng.integers(0, self.cfg.vocab,
                                size=(self.batch, self.seq + 1),
                                dtype=np.int32)
        out = {"tokens": toks[:, :-1].astype(np.int32),
               "labels": toks[:, 1:].astype(np.int32)}
        if self.cfg.n_vision_tokens:
            out["vision_embeds"] = rng.standard_normal(
                (self.batch, self.cfg.n_vision_tokens, self.cfg.d_model),
                dtype=np.float32).astype(np.dtype(self.cfg.dtype))
        return out


class PrefetchingLoader:
    """Wraps a pipeline with a device-put prefetch thread."""

    def __init__(self, pipeline, shardings, start_step: int = 0,
                 depth: int = 2):
        self.pipeline = pipeline
        self.shardings = shardings
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.step = start_step
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._worker, daemon=True)
        self.thread.start()

    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            batch = self.pipeline.global_batch(step)
            device_batch = jax.device_put(batch, self.shardings)
            self.q.put((step, device_batch))
            step += 1

    def __next__(self):
        return self.q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass


class PointStream:
    """Sharded point stream for the streaming K-means fit.

    Same determinism contract as :class:`TokenPipeline`: synthetic
    shard ``s`` is generated from ``rng((seed, s))``, so it is
    bit-identical on every epoch and every host — which is exactly what
    lets ``repro.streaming.StreamingKMeans`` key its carried-bounds
    cache on the shard id, and what makes restart-from-step need no
    loader state. ``data=`` instead wraps an existing (N, D) array —
    including an ``np.load(..., mmap_mode='r')`` memmap, the
    file-backed path — sliced into contiguous shards (the last shard
    may be short).

    ``global_batch(step)`` speaks the :class:`PrefetchingLoader`
    protocol (epochs wrap via ``step % n_shards``), so a device-put
    prefetch thread comes for free::

        loader = PrefetchingLoader(stream, None)
        skm.fit_stream(iter(loader.__next__, None), max_batches=...)
    """

    def __init__(self, shard_size: int = 1024, *, n_shards: int | None = None,
                 n_dims: int | None = None, k: int | None = None,
                 data: np.ndarray | None = None, seed: int = 0,
                 cluster_std: float = 1.0, spread: float = 8.0):
        if shard_size <= 0:
            raise ValueError("shard_size must be positive")
        self.shard_size = int(shard_size)
        self.seed = seed
        self.data = data
        if data is not None:
            if data.ndim != 2 or len(data) == 0:
                raise ValueError("data must be a non-empty (N, D) array")
            self.n_shards = -(-len(data) // self.shard_size)
            self.n_dims = data.shape[1]
        else:
            if not (n_shards and n_dims and k):
                raise ValueError(
                    "synthetic stream needs n_shards, n_dims and k")
            self.n_shards = int(n_shards)
            self.n_dims = int(n_dims)
            self.k = int(k)
            self.cluster_std = cluster_std
            # centers drawn once from (seed, 0); shard s from (seed, s+1)
            rng = np.random.default_rng((seed, 0))
            self._centers = rng.standard_normal(
                (self.k, self.n_dims)).astype(np.float32) * spread

    @classmethod
    def from_npy(cls, path: str, shard_size: int = 1024) -> "PointStream":
        """File-backed stream over a .npy array without loading it."""
        return cls(shard_size, data=np.load(path, mmap_mode="r"))

    @property
    def n_points(self) -> int:
        if self.data is not None:
            return len(self.data)
        return self.n_shards * self.shard_size

    def __len__(self) -> int:
        return self.n_shards

    def shard(self, idx: int) -> np.ndarray:
        """Shard ``idx`` (wraps modulo n_shards) as (B, D) float32."""
        idx = int(idx) % self.n_shards
        if self.data is not None:
            lo = idx * self.shard_size
            return np.asarray(self.data[lo:lo + self.shard_size],
                              np.float32)
        rng = np.random.default_rng((self.seed, idx + 1))
        assign = rng.integers(0, self.k, size=self.shard_size)
        pts = self._centers[assign] + rng.standard_normal(
            (self.shard_size, self.n_dims)).astype(np.float32) \
            * self.cluster_std
        return pts.astype(np.float32)

    def batches(self, epochs: int = 1, start: int = 0):
        """Yield ``(shard_id, points)`` over ``epochs`` full passes.
        ``start`` skips ahead to a global step mid-schedule — the
        restart-from-checkpoint entry point: because every shard is
        (seed, shard)-deterministic, resuming at step ``s`` yields
        bit-identical batches to the run that died there."""
        total = max(int(epochs), 1) * self.n_shards
        for step in range(int(start), total):
            s = step % self.n_shards
            yield s, self.shard(s)

    def global_batch(self, step: int) -> dict:
        s = step % self.n_shards
        return {"shard_id": s, "points": self.shard(s)}


def make_points(n: int, d: int, k: int, seed: int = 0,
                cluster_std: float = 1.0, spread: float = 8.0):
    """Gaussian-blob point cloud with ground-truth structure (the
    UCI-like synthetic stand-in; see configs/kpynq.py)."""
    rng = np.random.default_rng(seed)
    centers = rng.standard_normal((k, d)).astype(np.float32) * spread
    assign = rng.integers(0, k, size=n)
    pts = centers[assign] + rng.standard_normal((n, d)).astype(np.float32) \
        * cluster_std
    return pts.astype(np.float32), centers, assign
