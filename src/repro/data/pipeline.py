"""Data pipelines: sharded synthetic + file-backed token streams, and
point-cloud generators for the K-means workloads.

Design: the host produces GLOBAL batches deterministically from
(seed, step) — so any host can regenerate any step's batch, which is
what makes restart-from-checkpoint and elastic re-sharding trivial (no
data-loader state to persist beyond the step counter). A background
prefetch thread keeps ``depth`` batches ahead of the training loop
(compute/host-IO overlap).
"""
from __future__ import annotations

import queue
import threading

import jax
import numpy as np


class TokenPipeline:
    """Deterministic synthetic LM batches (or memory-mapped corpus)."""

    def __init__(self, cfg, batch: int, seq: int, seed: int = 0,
                 corpus: np.ndarray | None = None):
        self.cfg = cfg
        self.batch = batch
        self.seq = seq
        self.seed = seed
        self.corpus = corpus

    def global_batch(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        if self.corpus is not None:
            starts = rng.integers(0, len(self.corpus) - self.seq - 1,
                                  size=self.batch)
            toks = np.stack([self.corpus[s:s + self.seq + 1]
                             for s in starts])
        else:
            toks = rng.integers(0, self.cfg.vocab,
                                size=(self.batch, self.seq + 1),
                                dtype=np.int32)
        out = {"tokens": toks[:, :-1].astype(np.int32),
               "labels": toks[:, 1:].astype(np.int32)}
        if self.cfg.n_vision_tokens:
            out["vision_embeds"] = rng.standard_normal(
                (self.batch, self.cfg.n_vision_tokens, self.cfg.d_model),
                dtype=np.float32).astype(np.dtype(self.cfg.dtype))
        return out


class PrefetchingLoader:
    """Wraps a pipeline with a device-put prefetch thread."""

    def __init__(self, pipeline, shardings, start_step: int = 0,
                 depth: int = 2):
        self.pipeline = pipeline
        self.shardings = shardings
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.step = start_step
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._worker, daemon=True)
        self.thread.start()

    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            batch = self.pipeline.global_batch(step)
            device_batch = jax.device_put(batch, self.shardings)
            self.q.put((step, device_batch))
            step += 1

    def __next__(self):
        return self.q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass


def make_points(n: int, d: int, k: int, seed: int = 0,
                cluster_std: float = 1.0, spread: float = 8.0):
    """Gaussian-blob point cloud with ground-truth structure (the
    UCI-like synthetic stand-in; see configs/kpynq.py)."""
    rng = np.random.default_rng(seed)
    centers = rng.standard_normal((k, d)).astype(np.float32) * spread
    assign = rng.integers(0, k, size=n)
    pts = centers[assign] + rng.standard_normal((n, d)).astype(np.float32) \
        * cluster_std
    return pts.astype(np.float32), centers, assign
