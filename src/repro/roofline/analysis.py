"""Three-term roofline analysis from a compiled dry-run artifact.

  compute    = HLO_FLOPs    / (chips * PEAK_FLOPS)
  memory     = HLO_bytes    / (chips * HBM_BW)
  collective = coll_bytes   / (chips * ICI_BW)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``.
Collective bytes are NOT in cost_analysis: we parse the (per-device,
post-SPMD) HLO text and sum the result-shape bytes of every collective
op, then multiply by the chip count to get the global figure the
formula above divides back down.

Hardware constants: TPU v5e-class — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from typing import Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")

_ARRAY_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_LINE_RE = re.compile(
    r"^\s*\S+\s*=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[^\]]*\]\S*))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _ARRAY_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes_per_device(hlo_text: str) -> dict:
    """Sum result bytes of collective ops in a per-device HLO module.
    '-done' ops are skipped so async pairs aren't double counted."""
    per_op: dict[str, int] = {k: 0 for k in _COLL_OPS}
    counts: dict[str, int] = {k: 0 for k in _COLL_OPS}
    for m in _LINE_RE.finditer(hlo_text):
        shape_str, op = m.group(1), m.group(2)
        if "-done(" in m.group(0):
            continue
        per_op[op] += _shape_bytes(shape_str)
        counts[op] += 1
    return {"bytes": per_op, "counts": counts,
            "total": sum(per_op.values())}


def model_flops(cfg, kind: str, batch: int, seq: int) -> float:
    """6*N*D (train) / 2*N*D (inference) with MoE active-param scaling."""
    from ..models.transformer import param_shapes

    def leaf_count(tree, prefix=""):
        total = 0.0
        for k, v in tree.items():
            p = f"{prefix}/{k}" if prefix else k
            if isinstance(v, dict):
                total += leaf_count(v, p)
            else:
                n = 1
                for d in v:
                    n *= d
                name = p.split("/")[-1]
                if "moe" in p.split("/") and name != "router":
                    n *= cfg.moe_top_k / cfg.n_experts   # active fraction
                if name in ("embed",):
                    n = 0                                 # lookup, not matmul
                total += n
        return total

    n_active = leaf_count(param_shapes(cfg))
    tokens = batch * seq if kind in ("train", "prefill") else batch
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_active * tokens


def roofline(cost: dict, coll_total_per_dev: int, chips: int,
             cfg=None, kind: Optional[str] = None,
             batch: int = 0, seq: int = 0) -> dict:
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    # cost_analysis of the SPMD-partitioned module is per-device.
    t_compute = flops / PEAK_FLOPS
    t_memory = byts / HBM_BW
    t_coll = coll_total_per_dev / ICI_BW
    dom = max(("compute", t_compute), ("memory", t_memory),
              ("collective", t_coll), key=lambda kv: kv[1])[0]
    out = {
        "per_device_flops": flops,
        "per_device_bytes": byts,
        "per_device_collective_bytes": float(coll_total_per_dev),
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "bottleneck": dom,
        "chips": chips,
    }
    if cfg is not None and kind is not None:
        mf = model_flops(cfg, kind, batch, seq)
        out["model_flops_total"] = mf
        out["model_flops_per_device"] = mf / chips
        out["useful_flops_ratio"] = (mf / chips) / flops if flops else 0.0
        # roofline fraction: useful work / time implied by dominant term
        t_star = max(t_compute, t_memory, t_coll)
        out["roofline_fraction"] = ((mf / chips) / PEAK_FLOPS) / t_star \
            if t_star > 0 else 0.0
    return out


# --------------------------------------------------------------------------
# HLO-text cost model (fallback for programs whose compute lives in called
# computations that HloCostAnalysis does not traverse — observed for the
# shard_map K-means fit on the CPU backend; LLM cells don't need this).
# --------------------------------------------------------------------------

_OP_RE = re.compile(r"^\s*%\S+ = ([a-z0-9]+\[[0-9,]*\])[^\n]*? ([a-z0-9-]+)\(",
                    re.M)
_DOT_RE = re.compile(r"^\s*%\S+ = ([a-z0-9]+\[[0-9,]*\])[^\n]*? dot\(",
                     re.M)


def hlo_dot_flops(txt: str, contraction: int) -> float:
    """Sum 2*|out|*contraction over dot ops (caller supplies the known
    contraction size, e.g. the K-means feature dim)."""
    total = 0.0
    for m in _DOT_RE.finditer(txt):
        total += 2.0 * _shape_bytes(m.group(1)) / 4.0 * contraction
    return total


def hlo_traffic_bytes(txt: str, min_bytes: int = 1 << 20) -> float:
    """Approximate HBM traffic: 2x (write+read) the output bytes of every
    op larger than ``min_bytes`` in the optimized HLO (each listed op of
    the post-fusion module materialises its output once)."""
    total = 0.0
    for m in _OP_RE.finditer(txt):
        b = _shape_bytes(m.group(1))
        if b >= min_bytes and m.group(2) != "parameter":
            total += 2.0 * b
    return total
