"""Step functions: train / prefill / decode — the units the launcher jits.

All three are pure (state, batch) -> (state, out) functions built from a
config; distribution comes entirely from jit in_shardings/out_shardings
(GSPMD), so the same step runs on 1 chip or 512.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..models import decode_step, init_params, loss_fn, prefill_forward
from ..optim.adamw import AdamWConfig, adamw_update, init_moments


class TrainState(NamedTuple):
    step: jnp.ndarray
    params: dict
    m: dict
    v: dict


def init_train_state(key: jax.Array, cfg: ArchConfig) -> TrainState:
    params = init_params(key, cfg)
    m, v = init_moments(params)
    return TrainState(jnp.int32(0), params, m, v)


def make_train_step(cfg: ArchConfig, opt: AdamWConfig = AdamWConfig()):
    def train_step(state: TrainState, batch: dict):
        loss, grads = jax.value_and_grad(loss_fn)(state.params, batch, cfg)
        new_p, new_m, new_v, metrics = adamw_update(
            grads, state.m, state.v, state.params, state.step, opt)
        metrics["loss"] = loss
        return TrainState(state.step + 1, new_p, new_m, new_v), metrics

    return train_step


def make_prefill_step(cfg: ArchConfig):
    def prefill_step(params: dict, batch: dict):
        return prefill_forward(params, batch["tokens"], cfg,
                               vision_embeds=batch.get("vision_embeds"))

    return prefill_step


def make_serve_step(cfg: ArchConfig):
    def serve_step(params: dict, cache: dict, tokens: jnp.ndarray,
                   pos: jnp.ndarray):
        return decode_step(params, cache, tokens, pos, cfg)

    return serve_step
