"""Fault-tolerant runtime."""
from .fault_tolerance import (ElasticController, FailureInjector,
                              InjectedFailure, ResilientLoop,
                              StragglerWatchdog)

__all__ = ["ResilientLoop", "FailureInjector", "InjectedFailure",
           "StragglerWatchdog", "ElasticController"]
