"""Fault-tolerant training runtime: restart, stragglers, elasticity.

What a 1000-node deployment needs, implemented at the scale this
container can exercise (and unit-tested by injecting failures):

* ``ResilientLoop`` — drives (step fn, pipeline, checkpointer); on any
  step exception it restores the last good checkpoint and replays.
  Because the data pipeline is (seed, step)-deterministic, replay is
  bitwise-consistent — no data-loader state to recover.
* ``StragglerWatchdog`` — step-time EWMA; a step slower than
  ``threshold×`` the EWMA is flagged. On real multi-host topologies the
  remediation is re-scheduling the slow host (here: callback + metric).
  SPMD collectives make per-step progress lock-step, so detection (not
  per-node work stealing) is the actionable primitive.
* ``ElasticController`` — grow/shrink the mesh between runs: checkpoint
  under mesh A, rebuild shardings for mesh B, restore (see
  checkpoint.restore_checkpoint's sharding re-targeting).
* ``FailureInjector`` — deterministic chaos for tests.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax

from ..checkpoint.checkpoint import (latest_step, restore_checkpoint,
                                     save_checkpoint)


class InjectedFailure(RuntimeError):
    pass


@dataclass
class FailureInjector:
    """Raise InjectedFailure at the listed global steps (once each)."""
    fail_at: tuple = ()
    seen: set = field(default_factory=set)

    def check(self, step: int):
        if step in self.fail_at and step not in self.seen:
            self.seen.add(step)
            raise InjectedFailure(f"injected node failure at step {step}")


class StragglerWatchdog:
    def __init__(self, threshold: float = 3.0, alpha: float = 0.3,
                 on_straggler: Optional[Callable] = None):
        self.threshold = threshold
        self.alpha = alpha
        self.ewma: float | None = None
        self.events: list[dict] = []
        self.on_straggler = on_straggler

    def observe(self, step: int, dt: float) -> bool:
        is_straggler = (self.ewma is not None
                        and dt > self.threshold * self.ewma)
        if is_straggler:
            evt = {"step": step, "dt": dt, "ewma": self.ewma}
            self.events.append(evt)
            if self.on_straggler:
                self.on_straggler(evt)
        # EWMA excludes outliers so one straggler doesn't mask the next
        if not is_straggler:
            self.ewma = dt if self.ewma is None else \
                (1 - self.alpha) * self.ewma + self.alpha * dt
        return is_straggler

    def observe_shards(self, step: int, times) -> list[int]:
        """Per-shard variant: flag shards whose step time (or, under
        lockstep SPMD where wall-clock is indistinguishable, per-shard
        WORK from the telemetry ring's evals column — the distributed
        driver feeds that) exceeds ``threshold x`` the cross-shard
        median at this step. Returns the flagged shard indices; events
        carry the shard id. The EWMA tracks the median directly (one
        observation per step, outlier shards excluded by construction),
        so ``observe`` and ``observe_shards`` can share a watchdog."""
        import numpy as np

        times = np.asarray(times, np.float64)
        med = float(np.median(times))
        flagged: list[int] = []
        if med > 0:
            for s, dt in enumerate(times):
                if dt > self.threshold * med:
                    evt = {"step": step, "shard": int(s),
                           "dt": float(dt), "median": med}
                    self.events.append(evt)
                    flagged.append(int(s))
                    if self.on_straggler:
                        self.on_straggler(evt)
            self.ewma = med if self.ewma is None else \
                (1 - self.alpha) * self.ewma + self.alpha * med
        return flagged


class ResilientLoop:
    """Checkpoint/restart training driver.

    The default save/restore path treats ``state`` as a fixed-structure
    pytree of device arrays (``save_checkpoint`` / ``restore_checkpoint``).
    Drivers whose state is richer — host-side float64 ledgers, a bound
    cache whose pytree structure changes between checkpoints, scalars
    that live outside arrays (``repro.streaming``'s resilient layer is
    the canonical client) — inject their own serialization:

    * ``save_fn(state, step) -> Thread | None`` replaces the default
      checkpoint write (return the async writer thread, or ``None`` for
      a synchronous save);
    * ``restore_fn(state) -> (state, step)`` replaces the default
      restore (it decides its own ``like`` structure and device
      placement, and may fall back to an older complete checkpoint).
    """

    def __init__(self, step_fn, pipeline, ckpt_dir, *,
                 ckpt_every: int = 50, injector: FailureInjector | None = None,
                 watchdog: StragglerWatchdog | None = None,
                 max_restarts: int = 8, async_ckpt: bool = True,
                 save_fn=None, restore_fn=None):
        self.step_fn = step_fn
        self.pipeline = pipeline
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.injector = injector
        self.watchdog = watchdog or StragglerWatchdog()
        self.max_restarts = max_restarts
        self.async_ckpt = async_ckpt
        self.save_fn = save_fn
        self.restore_fn = restore_fn
        self.restarts = 0
        self.metrics_log: list[dict] = []

    def _save(self, state, step: int):
        if self.save_fn is not None:
            return self.save_fn(state, step)
        return save_checkpoint(self.ckpt_dir, step, state,
                               async_=self.async_ckpt)

    def _restore(self, state, state_shardings):
        if self.restore_fn is not None:
            return self.restore_fn(state)
        like = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
        return restore_checkpoint(self.ckpt_dir, like,
                                  shardings=state_shardings)

    def run(self, state, n_steps: int, *, state_shardings=None,
            start_step: int | None = None):
        if start_step is not None:
            step = int(start_step)
        else:
            step = int(jax.device_get(state.step)) \
                if hasattr(state, "step") else 0
        anchor = self._save(state, step)              # step anchor
        if anchor is not None:
            anchor.join()
        pending = None
        while step < n_steps:
            try:
                t0 = time.perf_counter()
                if self.injector:
                    self.injector.check(step)
                batch = self.pipeline.global_batch(step)
                state, metrics = self.step_fn(state, batch)
                if metrics:
                    jax.block_until_ready(jax.tree.leaves(metrics)[0])
                dt = time.perf_counter() - t0
                self.watchdog.observe(step, dt)
                self.metrics_log.append(
                    {"step": step, "dt": dt,
                     **{k: float(jax.device_get(v))
                        for k, v in metrics.items()}})
                step += 1
                if step % self.ckpt_every == 0:
                    if pending is not None:
                        pending.join()
                    pending = self._save(state, step)
            except InjectedFailure:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                if pending is not None:
                    pending.join()
                    pending = None
                state, step = self._restore(state, state_shardings)
        if pending is not None:
            pending.join()
        return state


class ElasticController:
    """Re-target a checkpoint from mesh A to mesh B (grow/shrink)."""

    def __init__(self, ckpt_dir):
        self.ckpt_dir = ckpt_dir

    def resume_on(self, like, new_shardings):
        state, step = restore_checkpoint(self.ckpt_dir, like,
                                         shardings=new_shardings)
        return state, step

    def has_checkpoint(self) -> bool:
        return latest_step(self.ckpt_dir) is not None
