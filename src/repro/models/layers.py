"""Shared transformer building blocks (pure JAX, bf16 compute/fp32 math)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(x.dtype)


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float):
    """x: (..., S, H, Dh) rotated pairwise; positions: (..., S)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (Dh/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (...,S,Dh/2)
    cos = jnp.cos(angles)[..., :, None, :]              # (..., S, 1, Dh/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def swiglu(x, w_gate, w_up, w_down):
    g = jnp.einsum("...d,df->...f", x, w_gate.astype(x.dtype))
    u = jnp.einsum("...d,df->...f", x, w_up.astype(x.dtype))
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u,
                      w_down.astype(x.dtype))


def cross_entropy_chunked(hidden, lm_head, labels, *, chunk: int = 1024,
                          mask=None, unroll: bool = False):
    """Chunked-over-sequence softmax CE so fp32 logits never materialise
    at (B, S, V). hidden: (B, S, D), lm_head: (D, V), labels: (B, S).
    Returns mean nll over unmasked tokens. ``unroll`` replaces the scan
    with a Python loop (analysis artifacts: exact HLO costs)."""
    b, s, d = hidden.shape
    n_chunks = max(s // chunk, 1)
    chunk = s // n_chunks
    h = hidden.reshape(b, n_chunks, chunk, d).swapaxes(0, 1)
    y = labels.reshape(b, n_chunks, chunk).swapaxes(0, 1)
    if mask is None:
        m = jnp.ones((n_chunks, b, chunk), jnp.float32)
    else:
        m = mask.reshape(b, n_chunks, chunk).swapaxes(0, 1).astype(jnp.float32)

    def body(carry, xs):
        hc, yc, mc = xs
        logits = jnp.einsum("bsd,dv->bsv", hc,
                            lm_head.astype(hc.dtype)).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yc[..., None], axis=-1)[..., 0]
        nll = (logz - gold) * mc
        loss_sum, n_tok = carry
        return (loss_sum + jnp.sum(nll), n_tok + jnp.sum(mc)), None

    carry = (jnp.float32(0), jnp.float32(0))
    if unroll:
        for i in range(n_chunks):
            carry, _ = body(carry, (h[i], y[i], m[i]))
    else:
        carry, _ = jax.lax.scan(body, carry, (h, y, m))
    loss_sum, n_tok = carry
    return loss_sum / jnp.maximum(n_tok, 1.0)
