"""Config-driven decoder assembly for every assigned architecture family.

Parameters are a plain pytree with per-layer leaves STACKED on a leading
``n_layers`` axis and the forward pass is a ``lax.scan`` over layers —
this keeps the HLO (and hence GSPMD partitioning time and program size)
independent of depth, which is what makes 94-layer × 512-device dry-run
compiles tractable. ``remat='full'`` wraps the scanned layer body in
``jax.checkpoint(nothing_saveable)`` (activation recompute in backward).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .attention import (_constrain, gqa_decode, gqa_train, mla_decode,
                        mla_train)
from .layers import cross_entropy_chunked, rms_norm, swiglu
from .mamba import mamba_mixer_decode, mamba_mixer_train
from .moe import moe_ffn

# ---------------------------------------------------------------------------
# parameter construction
# ---------------------------------------------------------------------------


def _layer_shapes(cfg: ArchConfig) -> dict:
    """Per-layer parameter shapes (without the stacked L axis)."""
    d = cfg.d_model
    s: dict = {"ln1": (d,)}
    if cfg.family != "ssm":
        h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        if cfg.mla is not None:
            m = cfg.mla
            s["attn"] = {
                "w_dq": (d, m.q_lora_rank),
                "w_uq": (m.q_lora_rank, h * (m.nope_dim + m.rope_dim)),
                "w_dkv": (d, m.kv_lora_rank),
                "w_kr": (d, m.rope_dim),
                "w_ukv": (m.kv_lora_rank, h * (m.nope_dim + m.v_dim)),
                "wo": (h * m.v_dim, d),
            }
        else:
            s["attn"] = {"wq": (d, h * dh), "wk": (d, kv * dh),
                         "wv": (d, kv * dh), "wo": (h * dh, d)}
            if cfg.qkv_bias:
                s["attn"].update({"bq": (h * dh,), "bk": (kv * dh,),
                                  "bv": (kv * dh,)})
    if cfg.family in ("ssm", "hybrid"):
        m = cfg.ssm
        gn = m.n_groups * m.d_state
        conv_ch = m.d_inner + 2 * gn
        s["mamba"] = {
            # z + xBC fused (16-divisible); dt separate (n_heads may be odd)
            "in_proj": (d, 2 * m.d_inner + 2 * gn),
            "dt_proj": (d, m.n_heads),
            "conv_w": (m.conv_width, conv_ch),
            "dt_bias": (m.n_heads,),
            "A_log": (m.n_heads,),
            "D": (m.n_heads,),
            "out_norm": (m.d_inner,),
            "out_proj": (m.d_inner, d),
        }
    if cfg.family == "hybrid":
        s["mix_na"] = (d,)
        s["mix_nm"] = (d,)
    if cfg.d_ff:
        s["ln2"] = (d,)
        if cfg.family == "moe":
            s["moe"] = {"router": (d, cfg.n_experts),
                        "w_gate": (cfg.n_experts, d, cfg.d_ff),
                        "w_up": (cfg.n_experts, d, cfg.d_ff),
                        "w_down": (cfg.n_experts, cfg.d_ff, d)}
        else:
            s["mlp"] = {"w_gate": (d, cfg.d_ff), "w_up": (d, cfg.d_ff),
                        "w_down": (cfg.d_ff, d)}
    return s


_FP32_LEAVES = ("A_log", "dt_bias", "D")
_ONES_LEAVES = ("ln1", "ln2", "out_norm", "mix_na", "mix_nm")


def param_shapes(cfg: ArchConfig) -> dict:
    """Full-model parameter shape tree (stacked layers)."""
    layer = jax.tree.map(lambda shp: (cfg.n_layers, *shp),
                         _layer_shapes(cfg),
                         is_leaf=lambda x: isinstance(x, tuple))
    tree = {"embed": (cfg.padded_vocab, cfg.d_model), "layers": layer,
            "final_norm": (cfg.d_model,)}
    if not cfg.tie_embeddings:
        tree["lm_head"] = (cfg.d_model, cfg.padded_vocab)
    return tree


def _leaf_dtype(path: str, cfg: ArchConfig):
    name = path.split("/")[-1]
    if name in _FP32_LEAVES:
        return jnp.float32
    return cfg.compute_dtype


def _flatten_with_path(tree, prefix=""):
    out = []
    for k, v in tree.items():
        p = f"{prefix}/{k}" if prefix else k
        if isinstance(v, dict):
            out += _flatten_with_path(v, p)
        else:
            out.append((p, v))
    return out


def init_params(key: jax.Array, cfg: ArchConfig) -> dict:
    """Materialise parameters (smoke/reduced scale; full scale goes
    through jax.eval_shape(init_params, ...) only)."""
    shapes = param_shapes(cfg)
    flat = _flatten_with_path(shapes)
    keys = jax.random.split(key, len(flat))

    def make(path, shape, k):
        name = path.split("/")[-1]
        dt = _leaf_dtype(path, cfg)
        if name in _ONES_LEAVES or name == "final_norm":
            return jnp.ones(shape, dt)
        if name == "A_log":
            return jnp.log(jnp.linspace(1.0, 16.0, shape[-1]) *
                           jnp.ones(shape, jnp.float32))
        if name == "dt_bias":
            return jnp.full(shape, -4.6, jnp.float32)   # softplus^-1(0.01)
        if name == "D":
            return jnp.ones(shape, jnp.float32)
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        std = 0.02 if name in ("embed", "lm_head") else fan_in ** -0.5
        return (jax.random.normal(k, shape, jnp.float32) * std).astype(dt)

    leaves = {p: make(p, shp, k) for (p, shp), k in zip(flat, keys)}

    def rebuild(tree, prefix=""):
        out = {}
        for k, v in tree.items():
            p = f"{prefix}/{k}" if prefix else k
            out[k] = rebuild(v, p) if isinstance(v, dict) else leaves[p]
        return out

    return rebuild(shapes)


# ---------------------------------------------------------------------------
# forward (training)
# ---------------------------------------------------------------------------


def _layer_train(x, lp, cfg: ArchConfig, positions):
    if cfg.batch_2d:
        # pin activations to 2D batch sharding; without the constraint
        # GSPMD propagates the params' 'model' dim instead and un-shards
        # the batch (measured: involuntary full rematerialization)
        x = _constrain(x, ("data", "model"), None, None)
    h = rms_norm(x, lp["ln1"])
    if cfg.family == "ssm":
        x = x + mamba_mixer_train(h, lp["mamba"], cfg)
    elif cfg.family == "hybrid":
        attn_out = gqa_train(h, lp["attn"], cfg, positions)
        mamba_out = mamba_mixer_train(h, lp["mamba"], cfg)
        x = x + 0.5 * (rms_norm(attn_out, lp["mix_na"]) +
                       rms_norm(mamba_out, lp["mix_nm"]))
    elif cfg.mla is not None:
        x = x + mla_train(h, lp["attn"], cfg, positions)
    else:
        x = x + gqa_train(h, lp["attn"], cfg, positions)
    if cfg.d_ff:
        h2 = rms_norm(x, lp["ln2"])
        if cfg.family == "moe":
            x = x + moe_ffn(h2, lp["moe"], cfg)
        else:
            x = x + swiglu(h2, lp["mlp"]["w_gate"], lp["mlp"]["w_up"],
                           lp["mlp"]["w_down"])
    return x


def forward(params, tokens, cfg: ArchConfig, vision_embeds=None):
    """tokens: (B, S) int32 -> final hidden states (B, S, D)."""
    b, s = tokens.shape
    dt = cfg.compute_dtype
    x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
    if vision_embeds is not None and cfg.n_vision_tokens:
        nv = cfg.n_vision_tokens
        vis = jnp.pad(vision_embeds.astype(dt),
                      ((0, 0), (0, s - nv), (0, 0)))
        keep = (jnp.arange(s) < nv)[None, :, None]
        x = jnp.where(keep, vis, x)
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    layer = functools.partial(_layer_train, cfg=cfg, positions=positions)
    if cfg.remat == "full":
        layer = jax.checkpoint(
            layer, policy=jax.checkpoint_policies.nothing_saveable)

    if cfg.unroll_layers:
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            x = layer(x, lp)
    else:
        def body(h, lp):
            return layer(h, lp), None

        x, _ = jax.lax.scan(body, x, params["layers"])
    return rms_norm(x, params["final_norm"])


def loss_fn(params, batch, cfg: ArchConfig):
    h = forward(params, batch["tokens"], cfg,
                vision_embeds=batch.get("vision_embeds"))
    lm_head = (params["embed"].T if cfg.tie_embeddings
               else params["lm_head"])
    return cross_entropy_chunked(h, lm_head, batch["labels"],
                                 chunk=cfg.loss_chunk,
                                 mask=batch.get("loss_mask"),
                                 unroll=cfg.unroll_chunks)


# ---------------------------------------------------------------------------
# prefill (serving: populate the cache in one parallel pass)
# ---------------------------------------------------------------------------


def _layer_prefill(x, lp, cfg: ArchConfig, positions):
    cache = {}
    h = rms_norm(x, lp["ln1"])
    if cfg.family == "ssm":
        out, st, cv = mamba_mixer_train(h, lp["mamba"], cfg,
                                        return_state=True)
        x = x + out
        cache.update(ssm=st, conv=cv)
    elif cfg.family == "hybrid":
        attn_out, k, v = gqa_train(h, lp["attn"], cfg, positions,
                                   return_kv=True)
        mamba_out, st, cv = mamba_mixer_train(h, lp["mamba"], cfg,
                                              return_state=True)
        x = x + 0.5 * (rms_norm(attn_out, lp["mix_na"]) +
                       rms_norm(mamba_out, lp["mix_nm"]))
        cache.update(k=k, v=v, ssm=st, conv=cv)
    elif cfg.mla is not None:
        out, kvc, kpe = mla_train(h, lp["attn"], cfg, positions,
                                  return_kv=True)
        x = x + out
        cache.update(kvc=kvc, kpe=kpe)
    else:
        out, k, v = gqa_train(h, lp["attn"], cfg, positions, return_kv=True)
        x = x + out
        if cfg.kv_cache_dtype == "int8":
            from .attention import quantize_kv
            k_q, k_s = quantize_kv(k)
            v_q, v_s = quantize_kv(v)
            cache.update(k=k_q, v=v_q, k_scale=k_s, v_scale=v_s)
        else:
            cache.update(k=k, v=v)
    if cfg.d_ff:
        h2 = rms_norm(x, lp["ln2"])
        if cfg.family == "moe":
            x = x + moe_ffn(h2, lp["moe"], cfg)
        else:
            x = x + swiglu(h2, lp["mlp"]["w_gate"], lp["mlp"]["w_up"],
                           lp["mlp"]["w_down"])
    return x, cache


def prefill_forward(params, tokens, cfg: ArchConfig, vision_embeds=None):
    """Parallel prefill: (B, S) tokens -> (last-token logits (B, 1, V),
    stacked per-layer cache covering positions [0, S))."""
    b, s = tokens.shape
    dt = cfg.compute_dtype
    x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
    if vision_embeds is not None and cfg.n_vision_tokens:
        nv = cfg.n_vision_tokens
        vis = jnp.pad(vision_embeds.astype(dt), ((0, 0), (0, s - nv), (0, 0)))
        keep = (jnp.arange(s) < nv)[None, :, None]
        x = jnp.where(keep, vis, x)
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    layer = functools.partial(_layer_prefill, cfg=cfg, positions=positions)
    if cfg.remat == "full":
        layer = jax.checkpoint(
            layer, policy=jax.checkpoint_policies.nothing_saveable)

    if cfg.unroll_layers:
        caches = []
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            x, c = layer(x, lp)
            caches.append(c)
        cache = jax.tree.map(lambda *xs: jnp.stack(xs), *caches)
    else:
        x, cache = jax.lax.scan(lambda h, lp: layer(h, lp), x,
                                params["layers"])
    h = rms_norm(x[:, -1:], params["final_norm"])
    lm_head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = jnp.einsum("bsd,dv->bsv", h, lm_head.astype(dt))
    return logits.astype(jnp.float32), cache


# ---------------------------------------------------------------------------
# decode (serving)
# ---------------------------------------------------------------------------


def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> dict:
    """Per-layer decode cache, stacked on L. Attention archs: KV (or MLA
    latent) cache; ssm archs: (H, N, P) recurrent state + conv ring."""
    L = cfg.n_layers
    dt = cfg.compute_dtype
    cache: dict = {}
    if cfg.family != "ssm":
        if cfg.mla is not None:
            m = cfg.mla
            cache["kvc"] = jnp.zeros((L, batch, max_len, m.kv_lora_rank), dt)
            cache["kpe"] = jnp.zeros((L, batch, max_len, m.rope_dim), dt)
        else:
            kv, dh = cfg.n_kv_heads, cfg.head_dim
            if cfg.kv_cache_dtype == "int8":
                cache["k"] = jnp.zeros((L, batch, max_len, kv, dh),
                                       jnp.int8)
                cache["v"] = jnp.zeros((L, batch, max_len, kv, dh),
                                       jnp.int8)
                cache["k_scale"] = jnp.zeros((L, batch, max_len, kv),
                                             jnp.float32)
                cache["v_scale"] = jnp.zeros((L, batch, max_len, kv),
                                             jnp.float32)
            else:
                cache["k"] = jnp.zeros((L, batch, max_len, kv, dh), dt)
                cache["v"] = jnp.zeros((L, batch, max_len, kv, dh), dt)
    if cfg.family in ("ssm", "hybrid"):
        m = cfg.ssm
        cache["ssm"] = jnp.zeros((L, batch, m.n_heads, m.d_state,
                                  m.head_dim), jnp.float32)
        cache["conv"] = jnp.zeros((L, batch, m.conv_width - 1,
                                   m.d_inner + 2 * m.n_groups * m.d_state),
                                  dt)
    return cache


def _layer_decode(x, lp, cl, cfg: ArchConfig, pos):
    new_cache = dict(cl)
    h = rms_norm(x, lp["ln1"])
    if cfg.family == "ssm":
        out, st, cv = mamba_mixer_decode(h, lp["mamba"], cfg,
                                         cl["ssm"], cl["conv"])
        x = x + out
        new_cache.update(ssm=st, conv=cv)
    elif cfg.family == "hybrid":
        attn_out, k, v = gqa_decode(h, lp["attn"], cfg, cl["k"], cl["v"], pos)
        mamba_out, st, cv = mamba_mixer_decode(h, lp["mamba"], cfg,
                                               cl["ssm"], cl["conv"])
        x = x + 0.5 * (rms_norm(attn_out, lp["mix_na"]) +
                       rms_norm(mamba_out, lp["mix_nm"]))
        new_cache.update(k=k, v=v, ssm=st, conv=cv)
    elif cfg.mla is not None:
        out, kvc, kpe = mla_decode(h, lp["attn"], cfg, cl["kvc"],
                                   cl["kpe"], pos)
        x = x + out
        new_cache.update(kvc=kvc, kpe=kpe)
    else:
        if cfg.kv_cache_dtype == "int8":
            out, k, v, (ks, vs) = gqa_decode(
                h, lp["attn"], cfg, cl["k"], cl["v"], pos,
                cache_scales=(cl["k_scale"], cl["v_scale"]))
            new_cache.update(k=k, v=v, k_scale=ks, v_scale=vs)
        else:
            out, k, v = gqa_decode(h, lp["attn"], cfg, cl["k"], cl["v"],
                                   pos)
            new_cache.update(k=k, v=v)
        x = x + out
    if cfg.d_ff:
        h2 = rms_norm(x, lp["ln2"])
        if cfg.family == "moe":
            x = x + moe_ffn(h2, lp["moe"], cfg)
        else:
            x = x + swiglu(h2, lp["mlp"]["w_gate"], lp["mlp"]["w_up"],
                           lp["mlp"]["w_down"])
    return x, new_cache


def decode_step(params, cache, tokens, pos, cfg: ArchConfig):
    """One serving step: tokens (B, 1) + cache at position ``pos`` ->
    (logits (B, 1, V), new cache)."""
    dt = cfg.compute_dtype
    x = jnp.take(params["embed"], tokens, axis=0).astype(dt)

    if cfg.unroll_layers:
        new_caches = []
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            cl = jax.tree.map(lambda a: a[i], cache)
            x, ncl = _layer_decode(x, lp, cl, cfg, pos)
            new_caches.append(ncl)
        new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *new_caches)
    else:
        def body(h, xs):
            lp, cl = xs
            h, ncl = _layer_decode(h, lp, cl, cfg, pos)
            return h, ncl

        x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    h = rms_norm(x, params["final_norm"])
    lm_head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = jnp.einsum("bsd,dv->bsv", h, lm_head.astype(dt))
    return logits.astype(jnp.float32), new_cache
