"""Mamba-2 (SSD — state-space duality) block, chunked-scan formulation.

The SSD primitive computes, per head h with state size N and head dim P:
    s_t = exp(dt_t * A_h) * s_{t-1} + dt_t * B_t x_t^T        (N x P state)
    y_t = C_t s_t + D_h x_t
The chunked algorithm (Dao & Gu 2024) splits the sequence into chunks of
Q tokens: an intra-chunk quadratic term (an attention-like (Q, Q) masked
matmul — MXU work) plus an inter-chunk recurrence carried by a
lax.scan over chunks (O(S/Q) sequential steps of (N x P) state math).
Decode keeps the (H, P, N) state + a conv ring buffer — O(1) per token,
which is why the ssm archs run the long_500k cell natively.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _causal_conv(xbc, conv_w, conv_cache=None):
    """Depthwise causal conv1d, window W. xbc: (B, S, C); conv_w: (W, C).
    With conv_cache (B, W-1, C) prepends history (decode path)."""
    w = conv_w.shape[0]
    if conv_cache is None:
        pad = jnp.zeros((xbc.shape[0], w - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = conv_cache.astype(xbc.dtype)
    full = jnp.concatenate([pad, xbc], axis=1)              # (B, S+W-1, C)
    out = sum(full[:, i:i + xbc.shape[1]] * conv_w[i][None, None].astype(xbc.dtype)
              for i in range(w))
    new_cache = full[:, -(w - 1):] if w > 1 else pad
    return jax.nn.silu(out), new_cache


def ssd_chunked(x, dt, A, B, C, D, *, chunk: int,
                return_state: bool = False):
    """x: (B,S,H,P); dt: (B,S,H); A: (H,)<0; B,C: (B,S,G,N); D: (H,).
    G (state groups) broadcasts over heads. Returns y: (B,S,H,P)
    (+ final recurrent state (B,H,N,P) fp32 when return_state)."""
    b, s_orig, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    q = min(chunk, s_orig)
    # pad S to a chunk multiple: dt=0 padding is exact (decay exp(0)=1,
    # zero discretised input -> padded steps are identity on the state)
    pad = (-s_orig) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    s = s_orig + pad
    nc = s // q
    rep = h // g

    xf = (x * dt[..., None]).astype(jnp.float32)            # discretised input
    la = dt.astype(jnp.float32) * A[None, None, :]          # log-decay per tok
    # reshape to chunks
    xc = xf.reshape(b, nc, q, h, p)
    lac = la.reshape(b, nc, q, h)
    Bc = B.reshape(b, nc, q, g, n).astype(jnp.float32)
    Cc = C.reshape(b, nc, q, g, n).astype(jnp.float32)

    cum = jnp.cumsum(lac, axis=2)                           # (B,NC,Q,H)
    total = cum[:, :, -1]                                   # (B,NC,H)

    # --- intra-chunk quadratic term ---------------------------------
    # decay(i<-j) = exp(cum_i - cum_j) for j <= i
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]    # (B,NC,Q,Q,H)
    mask = jnp.tril(jnp.ones((q, q), bool))
    decay = jnp.where(mask[None, None, :, :, None], jnp.exp(diff), 0.0)
    scores = jnp.einsum("bcign,bcjgn->bcijg", Cc, Bc)       # (B,NC,Q,Q,G)
    scores = jnp.repeat(scores, rep, axis=-1)               # broadcast to H
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", scores * decay, xc)

    # --- inter-chunk recurrence (scan over chunks) -------------------
    # chunk state contribution: sum_j exp(total - cum_j) B_j x_j
    w_in = jnp.exp(total[:, :, None, :] - cum)              # (B,NC,Q,H)
    Bh = jnp.repeat(Bc, rep, axis=3)                        # (B,NC,Q,H,N)
    state_in = jnp.einsum("bcjhn,bcjhp,bcjh->bchnp", Bh, xc, w_in)

    # Inter-chunk recurrence as a PARALLEL prefix (associative_scan):
    # element (s, t) composes as (s_b + s_a * exp(t_b), t_a + t_b) —
    # log-depth instead of a sequential while loop. This is both the
    # faster TPU formulation (no serial chain over chunks) and what
    # keeps HLO cost analysis trip-count-exact (no while body).
    def combine(a, bb):
        sa, ta = a
        sb, tb = bb
        return sa * jnp.exp(tb)[..., None, None] + sb, ta + tb

    inc_states, _ = jax.lax.associative_scan(
        combine, (state_in, total), axis=1)                 # (B,NC,H,N,P)
    prev_states = jnp.concatenate(
        [jnp.zeros((b, 1, h, n, p), jnp.float32), inc_states[:, :-1]],
        axis=1)
    final_state = inc_states[:, -1]

    w_out = jnp.exp(cum)                                    # decay from chunk start
    Ch = jnp.repeat(Cc, rep, axis=3)                        # (B,NC,Q,H,N)
    y_inter = jnp.einsum("bcihn,bchnp,bcih->bcihp", Ch, prev_states, w_out)

    y = (y_intra + y_inter).reshape(b, s, h, p)
    y = (y + x.astype(jnp.float32) * D[None, None, :, None]).astype(x.dtype)
    y = y[:, :s_orig]
    if return_state:
        return y, final_state
    return y


def ssd_decode_step(x, dt, A, B, C, D, state):
    """Single-token recurrence. x: (B,H,P); dt: (B,H); B,C: (B,G,N);
    state: (B,H,N,P) fp32. Returns (y (B,H,P), new_state)."""
    h, g = x.shape[1], B.shape[1]
    rep = h // g
    da = jnp.exp(dt.astype(jnp.float32) * A[None, :])       # (B,H)
    Bh = jnp.repeat(B.astype(jnp.float32), rep, axis=1)     # (B,H,N)
    Ch = jnp.repeat(C.astype(jnp.float32), rep, axis=1)
    xf = (x * dt[..., None]).astype(jnp.float32)
    new_state = state * da[:, :, None, None] + \
        jnp.einsum("bhn,bhp->bhnp", Bh, xf)
    y = jnp.einsum("bhn,bhnp->bhp", Ch, new_state)
    return (y + x.astype(jnp.float32) * D[None, :, None]).astype(x.dtype), new_state


def mamba_mixer_train(x, p, cfg, return_state: bool = False):
    """Full Mamba-2 mixer. x: (B, S, D) -> (B, S, D).
    return_state=True also returns (ssm_state, conv_cache) — prefill."""
    b, s, d = x.shape
    m = cfg.ssm
    di, gn = m.d_inner, m.n_groups * m.d_state
    proj = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(x.dtype))
    z = proj[..., :di]
    xbc_raw = proj[..., di:]
    dt = jnp.einsum("bsd,dh->bsh", x, p["dt_proj"].astype(x.dtype))
    xbc, conv_cache = _causal_conv(xbc_raw, p["conv_w"])
    xs = xbc[..., :di]
    Bm = xbc[..., di:di + gn].reshape(b, s, m.n_groups, m.d_state)
    Cm = xbc[..., di + gn:].reshape(b, s, m.n_groups, m.d_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32))  # (B,S,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))            # (H,)
    xh = xs.reshape(b, s, m.n_heads, m.head_dim)
    y = ssd_chunked(xh, dt, A, Bm, Cm, p["D"].astype(jnp.float32),
                    chunk=m.chunk, return_state=return_state)
    if return_state:
        y, final_state = y
    y = y.reshape(b, s, di)
    from .layers import rms_norm
    y = rms_norm(y * jax.nn.silu(z), p["out_norm"])
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(x.dtype))
    if return_state:
        return out, final_state, conv_cache
    return out


def mamba_mixer_decode(x, p, cfg, ssm_state, conv_cache):
    """x: (B, 1, D). ssm_state: (B,H,N,P) fp32; conv_cache: (B,W-1,C)."""
    b, _, d = x.shape
    m = cfg.ssm
    di, gn = m.d_inner, m.n_groups * m.d_state
    proj = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(x.dtype))
    z = proj[..., :di]
    xbc = proj[..., di:]
    dt = jnp.einsum("bsd,dh->bsh", x, p["dt_proj"].astype(x.dtype))
    xbc, conv_cache = _causal_conv(xbc, p["conv_w"], conv_cache)
    xs = xbc[..., :di]
    Bm = xbc[:, 0, di:di + gn].reshape(b, m.n_groups, m.d_state)
    Cm = xbc[:, 0, di + gn:].reshape(b, m.n_groups, m.d_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32))[:, 0]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xs[:, 0].reshape(b, m.n_heads, m.head_dim)
    y, ssm_state = ssd_decode_step(xh, dt, A, Bm, Cm,
                                   p["D"].astype(jnp.float32), ssm_state)
    y = y.reshape(b, 1, di)
    from .layers import rms_norm
    y = rms_norm(y * jax.nn.silu(z), p["out_norm"])
    return (jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(x.dtype)),
            ssm_state, conv_cache)
