"""Model substrate: config-driven decoder family."""
from .transformer import (decode_step, forward, init_cache, init_params,
                          loss_fn, param_shapes, prefill_forward)

__all__ = ["init_params", "param_shapes", "forward", "loss_fn",
           "decode_step", "init_cache", "prefill_forward"]
