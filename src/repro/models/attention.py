"""Attention variants: GQA (+MHA), MLA (multi-head latent attention).

Training uses query-chunked attention (lax.scan over query blocks with a
full key row per block) so the (S, S) score matrix never materialises —
peak activation is (B, q_chunk, H, S), which is what lets prefill_32k
fit per-device HBM. Decode takes a KV cache and a single query position.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .layers import apply_rope


def _constrain(t, *spec):
    """Best-effort sharding constraint (no-op outside a mesh context)."""
    try:
        return jax.lax.with_sharding_constraint(t, P(*spec))
    except Exception:
        return t


def _causal_chunk_attn(q, k, v, q_offset: jnp.ndarray, scale: float,
                       cp: bool = False):
    """q: (B, Cq, KV, R, Dh); k/v: (B, S, KV, Dh). Causal w.r.t. absolute
    positions q_offset + i vs j. fp32 softmax.

    cp=True (context-parallel): keep scores sharded over the KEY
    sequence dim on 'model'. KV-head counts (4/5/8/24/40) rarely divide
    the 16-way model axis — head sharding forces GSPMD to replicate or
    reshard the (B,KV,R,Cq,S) score tensor (measured: ~135 GB/layer of
    involuntary collectives on qwen2 prefill_32k). Sequence sharding
    always divides, turning that into one small psum per chunk."""
    s = k.shape[1]
    cq = q.shape[1]
    scores = jnp.einsum("bikrd,bjkd->bkrij", q, k).astype(jnp.float32) * scale
    if cp:
        scores = _constrain(scores, None, None, None, None, "model")
    q_pos = q_offset + jnp.arange(cq)[:, None]
    k_pos = jnp.arange(s)[None, :]
    mask = q_pos >= k_pos                                   # (Cq, S)
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bkrij,bjkd->bikrd", probs, v)


def gqa_train(x, p, cfg, positions, return_kv: bool = False):
    """x: (B, S, D) -> (B, S, D). p: attn param dict.
    return_kv=True additionally returns (k, v) (the prefill cache)."""
    b, s, d = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].reshape(d, h, dh).astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].reshape(d, kv, dh).astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].reshape(d, kv, dh).astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].reshape(h, dh).astype(x.dtype)
        k = k + p["bk"].reshape(kv, dh).astype(x.dtype)
        v = v + p["bv"].reshape(kv, dh).astype(x.dtype)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if cfg.attn_cp:
        k = _constrain(k, None, "model", None, None)
        v = _constrain(v, None, "model", None, None)
    scale = 1.0 / math.sqrt(dh)
    rep = h // kv
    qg = q.reshape(b, s, kv, rep, dh)

    cq = min(cfg.q_chunk, s)
    n_chunks = s // cq
    q_chunks = qg.reshape(b, n_chunks, cq, kv, rep, dh).swapaxes(0, 1)

    if cfg.unroll_chunks or cfg.causal_slice:
        # python chunk loop (exact HLO costs / static triangular slices)
        outs = []
        for i in range(n_chunks):
            if cfg.causal_slice:
                kk, vv = k[:, :(i + 1) * cq], v[:, :(i + 1) * cq]
            else:
                kk, vv = k, v
            outs.append(_causal_chunk_attn(q_chunks[i], kk, vv, i * cq,
                                           scale, cp=cfg.attn_cp))
        out = jnp.stack(outs)
    else:
        def body(_, xs):
            i, qc = xs
            return None, _causal_chunk_attn(qc, k, v, i * cq, scale,
                                            cp=cfg.attn_cp)

        _, out = jax.lax.scan(body, None,
                              (jnp.arange(n_chunks), q_chunks))
    out = out.swapaxes(0, 1).reshape(b, s, h * dh)
    out = jnp.einsum("bse,ed->bsd", out, p["wo"].astype(x.dtype))
    if return_kv:
        return out, k, v
    return out


def quantize_kv(t):
    """Per-(token, head) int8 quantization. t: (B, S, KV, Dh) ->
    (int8 values, fp32 scales (B, S, KV))."""
    scale = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1) / 127.0 + 1e-8
    q = jnp.clip(jnp.round(t.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_kv(q, scale, dtype):
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def gqa_decode(x, p, cfg, cache_k, cache_v, pos, cache_scales=None):
    """x: (B, 1, D); cache_k/v: (B, Smax, KV, Dh); pos: scalar index.
    Returns (out (B,1,D), new_k, new_v[, new_scales]).

    cache_scales=(k_scale, v_scale) each (B, Smax, KV) activates the
    int8 cache path: new entries are quantised per (token, head), the
    cache is dequantised on read — HBM cache traffic halves (the decode
    memory term is cache-read dominated at long S)."""
    b, _, d = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].reshape(d, h, dh).astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].reshape(d, kv, dh).astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].reshape(d, kv, dh).astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].reshape(h, dh).astype(x.dtype)
        k = k + p["bk"].reshape(kv, dh).astype(x.dtype)
        v = v + p["bv"].reshape(kv, dh).astype(x.dtype)
    posv = jnp.full((b, 1), pos)
    q = apply_rope(q, posv, cfg.rope_theta)
    k = apply_rope(k, posv, cfg.rope_theta)
    if cache_scales is not None:
        ks, vs = cache_scales
        k_q, k_s = quantize_kv(k)
        v_q, v_s = quantize_kv(v)
        cache_k = jax.lax.dynamic_update_slice(cache_k, k_q, (0, pos, 0, 0))
        cache_v = jax.lax.dynamic_update_slice(cache_v, v_q, (0, pos, 0, 0))
        ks = jax.lax.dynamic_update_slice(ks, k_s, (0, pos, 0))
        vs = jax.lax.dynamic_update_slice(vs, v_s, (0, pos, 0))
        k_full = dequantize_kv(cache_k, ks, x.dtype)
        v_full = dequantize_kv(cache_v, vs, x.dtype)
        new_scales = (ks, vs)
    else:
        cache_k = jax.lax.dynamic_update_slice(
            cache_k, k.astype(cache_k.dtype), (0, pos, 0, 0))
        cache_v = jax.lax.dynamic_update_slice(
            cache_v, v.astype(cache_v.dtype), (0, pos, 0, 0))
        k_full = cache_k.astype(x.dtype)
        v_full = cache_v.astype(x.dtype)
        new_scales = None
    smax = cache_k.shape[1]
    rep = h // kv
    qg = q.reshape(b, 1, kv, rep, dh)
    scale = 1.0 / math.sqrt(dh)
    scores = jnp.einsum("bikrd,bjkd->bkrij", qg,
                        k_full).astype(jnp.float32) * scale
    valid = (jnp.arange(smax) <= pos)[None, None, None, None, :]
    scores = jnp.where(valid, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkrij,bjkd->bikrd", probs, v_full)
    out = out.reshape(b, 1, h * dh)
    out = jnp.einsum("bse,ed->bsd", out, p["wo"].astype(x.dtype))
    if cache_scales is not None:
        return out, cache_k, cache_v, new_scales
    return out, cache_k, cache_v


# ---------------------------------------------------------------------------
# MLA — Multi-head Latent Attention (MiniCPM3 / DeepSeek-V2 style)
# ---------------------------------------------------------------------------

def _mla_qkv(x, p, cfg, positions):
    """Shared projection math for MLA train/decode.

    Returns q (B,S,H,nope+rope), kv_c (B,S,r_kv), k_pe (B,S,rope)."""
    m = cfg.mla
    q_c = jnp.einsum("bsd,dr->bsr", x, p["w_dq"].astype(x.dtype))
    q_c = q_c  # (optionally normed; lora-norm folded into init for brevity)
    q = jnp.einsum("bsr,rhk->bshk", q_c,
                   p["w_uq"].reshape(m.q_lora_rank, cfg.n_heads,
                                     m.nope_dim + m.rope_dim).astype(x.dtype))
    q_nope, q_pe = q[..., :m.nope_dim], q[..., m.nope_dim:]
    q_pe = apply_rope(q_pe, positions, cfg.rope_theta)
    q = jnp.concatenate([q_nope, q_pe], axis=-1)
    kv_c = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"].astype(x.dtype))
    k_pe = jnp.einsum("bsd,dk->bsk", x, p["w_kr"].astype(x.dtype))
    k_pe = apply_rope(k_pe[:, :, None, :], positions,
                      cfg.rope_theta)[:, :, 0, :]
    return q, kv_c, k_pe


def _mla_attend(q, kv_c, k_pe, p, cfg):
    """Attention over latent cache. q: (B,Sq,H,nope+rope);
    kv_c: (B,S,r); k_pe: (B,S,rope). Causality handled by caller mask."""
    m = cfg.mla
    h = cfg.n_heads
    w_ukv = p["w_ukv"].reshape(m.kv_lora_rank, h, m.nope_dim + m.v_dim)
    k_nope = jnp.einsum("bsr,rhk->bshk", kv_c,
                        w_ukv[..., :m.nope_dim].astype(kv_c.dtype))
    v = jnp.einsum("bsr,rhk->bshk", kv_c,
                   w_ukv[..., m.nope_dim:].astype(kv_c.dtype))
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_pe[:, :, None, :],
                                  (*k_pe.shape[:2], h, m.rope_dim))], axis=-1)
    return q, k, v


def mla_train(x, p, cfg, positions, return_kv: bool = False):
    b, s, d = x.shape
    m = cfg.mla
    q, kv_c, k_pe = _mla_qkv(x, p, cfg, positions)
    q, k, v = _mla_attend(q, kv_c, k_pe, p, cfg)
    scale = 1.0 / math.sqrt(m.nope_dim + m.rope_dim)
    h = cfg.n_heads
    qg = q[:, :, :, None, :].reshape(b, s, h, 1, -1)
    cq = min(cfg.q_chunk, s)
    n_chunks = s // cq
    q_chunks = qg.reshape(b, n_chunks, cq, h, 1, qg.shape[-1]).swapaxes(0, 1)

    if cfg.attn_cp:
        k = _constrain(k, None, "model", None, None)
        v = _constrain(v, None, "model", None, None)
    if cfg.unroll_chunks or cfg.causal_slice:
        outs = []
        for i in range(n_chunks):
            if cfg.causal_slice:
                kk, vv = k[:, :(i + 1) * cq], v[:, :(i + 1) * cq]
            else:
                kk, vv = k, v
            outs.append(_causal_chunk_attn(q_chunks[i], kk, vv, i * cq,
                                           scale, cp=cfg.attn_cp))
        out = jnp.stack(outs)
    else:
        def body(_, xs):
            i, qc = xs
            return None, _causal_chunk_attn(qc, k, v, i * cq, scale,
                                            cp=cfg.attn_cp)

        _, out = jax.lax.scan(body, None, (jnp.arange(n_chunks), q_chunks))
    out = out.swapaxes(0, 1).reshape(b, s, h * m.v_dim)
    out = jnp.einsum("bse,ed->bsd", out, p["wo"].astype(x.dtype))
    if return_kv:
        return out, kv_c, k_pe
    return out


def mla_decode(x, p, cfg, cache_kvc, cache_kpe, pos):
    """MLA decode caches the COMPRESSED latents (B, Smax, r_kv) +
    (B, Smax, rope) — the whole point of MLA's cache saving."""
    b = x.shape[0]
    m = cfg.mla
    posv = jnp.full((b, 1), pos)
    q, kv_c, k_pe = _mla_qkv(x, p, cfg, posv)
    cache_kvc = jax.lax.dynamic_update_slice(
        cache_kvc, kv_c.astype(cache_kvc.dtype), (0, pos, 0))
    cache_kpe = jax.lax.dynamic_update_slice(
        cache_kpe, k_pe.astype(cache_kpe.dtype), (0, pos, 0))
    q, k, v = _mla_attend(q, cache_kvc.astype(x.dtype),
                          cache_kpe.astype(x.dtype), p, cfg)
    scale = 1.0 / math.sqrt(m.nope_dim + m.rope_dim)
    scores = jnp.einsum("bihd,bjhd->bhij", q, k).astype(jnp.float32) * scale
    smax = cache_kvc.shape[1]
    valid = (jnp.arange(smax) <= pos)[None, None, None, :]
    scores = jnp.where(valid, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhij,bjhd->bihd", probs, v)
    out = out.reshape(b, 1, cfg.n_heads * m.v_dim)
    return (jnp.einsum("bse,ed->bsd", out, p["wo"].astype(x.dtype)),
            cache_kvc, cache_kpe)
