"""Token-choice top-k MoE with sort-based (MegaBlocks-style) dispatch.

Dense dispatch one-hots of shape (T, E, C) are ruled out at 32k-seq
scale; instead tokens are argsorted by destination expert and packed
into an (E, capacity, D) buffer — the batched expert matmul then runs
at *active*-parameter FLOPs (6·N_active·D), which is what the roofline
MODEL_FLOPS accounting expects. Expert-parallel sharding puts the E
axis of the buffer and the expert weights on the 'model' mesh axis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def moe_ffn(x, p, cfg):
    """x: (B, S, D) -> (B, S, D). p: {'router': (D,E), 'w_gate'/'w_up':
    (E, D, F), 'w_down': (E, F, D)}."""
    b, s, d = x.shape
    e, topk = cfg.n_experts, cfg.moe_top_k
    t = b * s
    xf = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", xf,
                        p["router"].astype(x.dtype)).astype(jnp.float32)
    gates, experts = jax.lax.top_k(logits, topk)            # (T, k)
    gates = jax.nn.softmax(gates, axis=-1)

    # flatten (token, k) pairs and sort by expert id
    flat_expert = experts.reshape(-1)                       # (T*k,)
    flat_token = jnp.repeat(jnp.arange(t), topk)
    flat_gate = gates.reshape(-1)
    order = jnp.argsort(flat_expert)
    se, st, sg = flat_expert[order], flat_token[order], flat_gate[order]

    # position of each entry within its expert group
    counts = jnp.bincount(se, length=e)                     # (E,)
    starts = jnp.concatenate([jnp.zeros(1, counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    pos_in_group = jnp.arange(t * topk) - starts[se]

    cap = int(cfg.moe_capacity_factor * t * topk / e) + 1
    keep = pos_in_group < cap
    dest = se * cap + jnp.where(keep, pos_in_group, 0)

    buf = jnp.zeros((e * cap, d), x.dtype).at[dest].set(
        jnp.where(keep[:, None], xf[st], 0), mode="drop")
    buf = buf.reshape(e, cap, d)

    # batched expert SwiGLU — the active-FLOPs matmuls
    g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(x.dtype))
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u,
                   p["w_down"].astype(x.dtype))

    # un-sort: gather back and weighted scatter-add into tokens
    y_flat = y.reshape(e * cap, d)[dest] * jnp.where(keep, sg, 0)[:, None].astype(x.dtype)
    out = jnp.zeros((t, d), x.dtype).at[st].add(y_flat)
    return out.reshape(b, s, d)
