"""Fault-tolerant streaming fits: checkpoint / restore / replay.

This is the glue between three pieces that already exist on their own:

* :class:`repro.streaming.StreamingKMeans` — the bound-carrying
  mini-batch estimator, which can now snapshot/restore its FULL stream
  state (centroids, EMA counts, float64 drift ledger, per-shard bound
  cache, reseed reservoir, stats);
* :mod:`repro.checkpoint` — atomic async sharded saves with validated,
  corrupt-tolerant restore;
* :class:`repro.runtime.fault_tolerance.ResilientLoop` — the
  restart-on-failure driver, with `FailureInjector` chaos hooks.

The recovery contract is REPLAY, not approximation: the stream source
must speak the deterministic ``global_batch(step)`` protocol
(:class:`repro.data.PointStream` does — shard ``s`` regenerates
bit-identically from ``rng((seed, s+1))``), so after a failure the
loop restores the newest complete checkpoint and re-runs the exact
batches the dead run saw after it. Every replayed step re-executes the
same jitted programs on bit-identical inputs (the checkpoint restores
every input bit-for-bit, including the float64 ledger, which never
transits a device), so the centroids, counts, ledger and bound cache
land bit-identical to an uninterrupted run. Only :class:`StreamStats`
legitimately differs — replayed work is still work, and is counted
(``replayed_batches``, ``restores``, ``ckpt_saves``).

Elasticity rides on the same mechanism: a checkpoint taken under one
mesh restores under any other (or none) — cached bounds are stored
unpadded per shard, and the estimator re-pads batches and rebuilds its
capacity ladders lazily against the CURRENT mesh. Exact bit-parity
holds for equal reduction topologies; across a resize the psum
partitioning changes, so the guarantee weakens to numerical parity
(identical assignments / inertia to fp tolerance) — see
``docs/fault_tolerance.md``.

Observability: with ``obs`` enabled on the estimator, recovery is
visible — ``ckpt_saves_total`` / ``ckpt_save_seconds`` /
``ckpt_last_step``, ``restore_total`` / ``restore_step``,
``replay_batches_total``, and ``ckpt_save`` / ``restore`` events in
the registry's event log.
"""
from __future__ import annotations

import time

from ..checkpoint.checkpoint import available_steps
from ..runtime.fault_tolerance import ResilientLoop


class _TrackingPipeline:
    """global_batch passthrough that remembers the step it served —
    the step_fn needs the schedule index to count replays, and the
    ResilientLoop protocol doesn't pass it through."""

    def __init__(self, stream):
        self.stream = stream
        self.last_step = 0

    def global_batch(self, step: int):
        self.last_step = step
        return self.stream.global_batch(step)


def fit_stream_resilient(skm, stream, *, ckpt_dir, epochs: int = 1,
                         max_batches: int | None = None,
                         ckpt_every: int = 8, injector=None,
                         watchdog=None, max_restarts: int = 8,
                         async_ckpt: bool = True, resume: bool = True):
    """Drive ``skm`` over ``stream`` with checkpoint/restore-replay
    fault tolerance (see module docstring for the exact contract).

    ``stream`` must provide ``global_batch(step)`` and ``__len__``
    (batches per epoch). ``ckpt_every`` is in batches; saves are async
    by default (the writer thread is joined before the next save and at
    exit). ``resume=True`` picks up an existing checkpoint directory —
    the elastic-restart entry point: construct the estimator with the
    NEW mesh (or use :meth:`StreamingKMeans.restore`) and the state
    re-pads into it. Failures beyond ``max_restarts`` re-raise.
    """
    if not (hasattr(stream, "global_batch") and hasattr(stream, "__len__")):
        raise ValueError(
            "resilient fit needs a deterministic global_batch(step) "
            "stream with a known length (e.g. repro.data.PointStream); "
            "got " + type(stream).__name__)
    n_steps = max(int(epochs), 1) * len(stream)
    if max_batches is not None:
        n_steps = min(n_steps, int(max_batches))
    reg = skm._obs.resolve_registry() if skm._obs is not None else None

    start = 0
    if resume and available_steps(ckpt_dir):
        start = skm.restore_state(ckpt_dir, fallback=True)
        if reg is not None:
            reg.counter("restore_total", "stream-state restores").inc()
            reg.gauge("restore_step",
                      "schedule step of the last restore").set(start)
            reg.log_event("restore", step=start, reason="resume")
    pipe = _TrackingPipeline(stream)
    high_water = start

    def step_fn(state, batch):
        nonlocal high_water
        step = pipe.last_step
        if step < high_water:
            skm.stats_.replayed_batches += 1
            if reg is not None:
                reg.counter("replay_batches_total",
                            "batches re-run after a restore").inc()
        else:
            high_water = step + 1
        skm.partial_fit(batch["points"], shard_id=batch["shard_id"],
                        sample_weight=batch.get("sample_weight"))
        return skm, {}

    def save_fn(state, step):
        if not skm.initialized:
            return None        # nothing to save during the cold start
        t0 = time.perf_counter()
        thread = skm.save(ckpt_dir, step, async_=async_ckpt)
        if reg is not None:
            reg.counter("ckpt_saves_total",
                        "stream-state checkpoints written").inc()
            reg.gauge("ckpt_last_step",
                      "schedule step of the last checkpoint").set(step)
            reg.histogram(
                "ckpt_save_seconds",
                "state snapshot (plus write when sync)").observe(
                time.perf_counter() - t0)
            reg.log_event("ckpt_save", step=step,
                          cache_entries=len(skm._cache),
                          async_=bool(async_ckpt))
        return thread

    def restore_fn(state):
        if available_steps(ckpt_dir):
            step = skm.restore_state(ckpt_dir, fallback=True)
            reason = "failure"
        else:
            # died before the first complete checkpoint: cold restart;
            # replaying the deterministic stream from step 0 reproduces
            # the original cold start bit-for-bit
            skm.reset_state()
            skm.stats_.restores += 1
            step, reason = 0, "failure-before-first-checkpoint"
        if reg is not None:
            reg.counter("restore_total", "stream-state restores").inc()
            reg.gauge("restore_step",
                      "schedule step of the last restore").set(step)
            reg.log_event("restore", step=step, reason=reason)
        return skm, step

    loop = ResilientLoop(step_fn, pipe, ckpt_dir, ckpt_every=ckpt_every,
                         injector=injector, watchdog=watchdog,
                         max_restarts=max_restarts, async_ckpt=async_ckpt,
                         save_fn=save_fn, restore_fn=restore_fn)
    loop.run(skm, n_steps, start_step=start)
    if skm.initialized:
        # terminal sync save so a later resume continues exactly here
        skm.save(ckpt_dir, n_steps, async_=False)
        if reg is not None:
            reg.counter("ckpt_saves_total",
                        "stream-state checkpoints written").inc()
            reg.gauge("ckpt_last_step",
                      "schedule step of the last checkpoint").set(n_steps)
    return skm
