"""Streaming / mini-batch K-means on the device-resident engine.

``StreamingKMeans.partial_fit`` feeds point shards through the engine's
two-level-filtered candidate pass with triangle-inequality bounds
CARRIED across batches (see ``estimator.py`` for the full design).
"""
from .estimator import StreamingKMeans
from .resilient import fit_stream_resilient
from .state import (BoundCache, DriftLedger, ShardBounds, StreamStats,
                    inflate_bounds)

__all__ = [
    "StreamingKMeans", "StreamStats", "ShardBounds", "DriftLedger",
    "BoundCache", "inflate_bounds", "fit_stream_resilient",
]
