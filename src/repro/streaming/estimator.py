"""StreamingKMeans: bound-carrying mini-batch K-means on the
device-resident engine.

The batch engine (``repro.core.engine``) realises KPynq's two filter
levels as skipped work inside one fit; this estimator extends the same
candidate pass to point streams that never fit in memory at once:

1. **Ingest** — ``partial_fit(batch, shard_id=...)`` or
   ``fit_stream(PointStream, epochs=...)``. A shard id is a promise
   that the same id always carries the same points (which the
   deterministic ``(seed, shard)`` generation in
   :class:`repro.data.PointStream` keeps for free).
2. **Bound carry** — on a shard revisit the cached filter state is
   re-validated by :func:`inflate_bounds` (upper bounds grow by each
   point's assigned-centroid drift accumulated in the
   :class:`DriftLedger`; group lower bounds shrink by their group's
   max drift), then the engine's point-level filter
   (:func:`repro.core.engine.stream_bounds`) decides which points need
   distance work at all. First visits run with vacuous bounds —
   exactly the batch fit's first-iteration semantics.
3. **Candidate pass + update** —
   :func:`repro.core.engine.stream_step` (the engine's PassCore
   instantiated with the streaming EMA update rule): the
   capacity-bucketed two-level compacted candidate pass (point
   survivors stream-compacted into a pow2 bucket sized from the synced
   candidate count; the group bucket sized from the shard's last-visit
   high-water with the engine's ``lax.cond`` dense spill), then the
   decayed count-weighted centroid EMA, then post-move bound decay so
   the stored cache entry is valid against the new centroids. No dense
   (N, K) distance matrix is ever built in this path.
4. **Upkeep** — drift ledger accumulation, dead-centroid patience +
   re-seeding from a far-point reservoir, EWA inertia estimate, and
   :class:`StreamStats` (batches, distance evals, cache hits/misses,
   drift resets, reseeds).

Decay schedule: effective per-centroid counts are multiplied by
``decay`` before each update. ``decay=1.0`` (default) is Sculley-style
pure count-weighting — the learning rate for centroid c decays as
1/n_c, the right choice for stationary streams and for converging to
the batch fit (``tests/test_streaming.py`` checks the inertia gap).
``decay<1`` caps the memory at roughly ``1/(1-decay)`` batches per
centroid — the right choice for drifting streams, at the cost of a
noise floor.

Cold start: batches are buffered until ``init_size`` points (default
``2 * n_clusters``; raise it to seed from several shards) are
available, then centroids are seeded by k-means++ over the buffer,
centroid groups are built once (they stay fixed; drift handles all
subsequent movement), and the buffered batches are replayed through
the normal step so their bounds enter the cache.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..core import engine as _engine
from ..core.api import NotFittedError
from ..core.engine import PassCore, _bucket_cap
from ..core.init import kmeans_plusplus, random_init
from ..core.kmeans import group_centroids
from ..obs.metrics import normalize_obs
from .state import (BoundCache, DriftLedger, ShardBounds, StreamStats,
                    inflate_bounds)


class StreamingKMeans:
    """sklearn-style streaming K-means estimator (see module docstring).

    Parameters
    ----------
    n_clusters : K
    n_groups : Yinyang group count (default K//10; 1 = Hamerly filter)
    init : 'k-means++' | 'random' — seeding over the cold-start buffer
    decay : count decay per batch (1.0 = pure count-weighting)
    init_size : points buffered before seeding (default 2*K)
    min_bucket : floor of the pow2 candidate-capacity lattice
    max_cached_shards : LRU size of the per-shard bound cache
    reseed_patience : full stream passes (distinct-shards-seen worth of
        batches) without points before a centroid is re-seeded from the
        far-point reservoir — scaled this way so a centroid served by a
        shard late in a long epoch is not declared dead mid-pass
    drift_reset_factor : drop a cached shard when accumulated group
        drift exceeds this multiple of its stored mean ub (bounds still
        valid, just vacuous — recomputing beats carrying them)
    mesh / mesh_axes : a ``jax.sharding.Mesh`` (+ the point-sharded
        axis names) routes every batch through the DISTRIBUTED step:
        the global batch is split over ``mesh_axes``, each device runs
        the engine's compacted candidate pass on its slice, and the
        psum'd batch sums/counts feed the decayed EMA
        (:func:`repro.core.distributed.make_stream_update_sharded`).
        Batches that do not divide the shard count are padded with
        sentinel rows (zero cost, no statistics). The drift ledger and
        bound cache operate on the REDUCED (replicated) move, so the
        whole bound-carry machinery is unchanged. ``mesh=None``
        (default) keeps the single-device step.
    obs : observability switch (see :mod:`repro.obs`) — when enabled,
        each batch publishes points/s, batch wall-clock, cumulative
        drift-ledger magnitude, bound-cache hit/miss counters and
        reseeds to the metrics registry, plus one ``stream_batch``
        event (batch size, candidate count, pairs scored, cache hit).
        Pure host-side bookkeeping around the step's existing blocking
        fetch — device programs and results are unchanged.
    tune : 'auto' | 'off' — consult the per-(platform, B, K, D)
        tuning cache (:mod:`repro.tune`) at cold-start time (B = the
        first batch's size) and adopt the tuned ``min_cap`` -> bucket
        floor, ``chunk`` and group-gather crossover for the per-batch
        candidate passes. Explicitly passed ``min_bucket`` / ``chunk``
        always win over tuned values. The streaming path never runs
        the measured search itself ('force' degrades to 'auto' here —
        tune the batch signature with :func:`repro.tune.autotune` if
        you want one); results are identical either way.
    """

    def __init__(self, n_clusters: int, *, n_groups: int | None = None,
                 init: str = "k-means++", decay: float = 1.0,
                 init_size: int | None = None, seed: int = 0,
                 min_bucket: int | None = None,
                 max_cached_shards: int = 256,
                 reseed_patience: int = 20,
                 drift_reset_factor: float = 8.0,
                 chunk: int | None = None,
                 tune: str = "auto",
                 mesh=None, mesh_axes=("data",), obs=None):
        if init not in ("k-means++", "random"):
            raise ValueError(f"unknown init {init!r}")
        if not 0.0 < decay <= 1.0:
            raise ValueError("decay must be in (0, 1]")
        if tune not in ("auto", "off", "force"):
            raise ValueError(f"unknown tune mode {tune!r}; expected "
                             f"'auto', 'off' or 'force'")
        self.n_clusters = int(n_clusters)
        self.n_groups = n_groups
        self.init = init
        self.decay = float(decay)
        self.init_size = init_size
        self.seed = seed
        # None = "use default, tunable"; an explicit value always wins
        # over the tuned config (same precedence as engine.fit kwargs)
        self._explicit_min_bucket = min_bucket is not None
        self._explicit_chunk = chunk is not None
        self.min_bucket = int(min_bucket) if min_bucket is not None else 256
        self.reseed_patience = int(reseed_patience)
        self.drift_reset_factor = float(drift_reset_factor)
        self.chunk = int(chunk) if chunk is not None else 2048
        self.tune = tune
        self._ggf = 4                     # group-gather crossover factor
        self.mesh = mesh
        self.mesh_axes = tuple(mesh_axes)
        self._n_shards = 1
        if mesh is not None:
            from ..core.distributed import _mesh_shards
            self._n_shards = _mesh_shards(mesh, self.mesh_axes)
        self._sharded_bounds = None       # built lazily per mesh
        self._sharded_updates: dict = {}  # (cap_n, cap_g) -> jitted step

        self._obs = normalize_obs(obs)
        self.stats_ = StreamStats()
        self.ewa_inertia_: float | None = None
        self._ewa_alpha = 0.25
        self._centroids = None            # (K, D) device array once live
        self._counts = None               # (K,) device array
        self._buffer: list = []           # [(shard_id, np points)] pre-init
        self._buffered = 0
        self._cache = BoundCache(max_cached_shards)
        self._ledger: DriftLedger | None = None
        self._labels_last: np.ndarray | None = None
        # chaos-test seam: called inside _step AFTER the device update
        # lands but BEFORE the host-side commit (ledger, cache, stats).
        # Raising here models a host crash mid-batch — the estimator is
        # left TORN (device centroids advanced, host bookkeeping not)
        # and only a checkpoint restore makes it consistent again.
        self.chaos_hook = None
        # continuous-refresh seam: a repro.serve.CentroidIndex that
        # receives a publish after every _publish_every committed
        # batches (see attach_index)
        self._serve_index = None
        self._publish_every = 1

    # -- lifecycle ---------------------------------------------------------

    @property
    def initialized(self) -> bool:
        return self._centroids is not None

    def _require_fitted(self):
        if not self.initialized:
            raise NotFittedError(
                "This StreamingKMeans instance has no centroids yet; "
                "call partial_fit()/fit_stream() (enough points to cover "
                "init_size) first.")

    def _resolved_groups(self) -> int:
        g = self.n_groups
        if g is None:
            g = max(self.n_clusters // 10, 1)
        return int(min(g, self.n_clusters))

    def _initialize(self) -> None:
        buf = np.concatenate([p for _, p, _ in self._buffer], axis=0)
        k = self.n_clusters
        if len(buf) < k:
            raise ValueError(
                f"need at least n_clusters={k} buffered points to "
                f"initialize, got {len(buf)}")
        pts = jnp.asarray(buf)
        key = jax.random.PRNGKey(self.seed)
        # Weighted cold start: when any buffered batch carried weights,
        # seed by weighted D^2 sampling (weightless batches count as
        # weight 1.0). An all-None buffer keeps the original unweighted
        # seeding program bit-identically.
        buf_w = None
        if any(w is not None for _, _, w in self._buffer):
            buf_w = np.concatenate(
                [w if w is not None else np.ones((len(p),), np.float32)
                 for _, p, w in self._buffer], axis=0)
        if self.init == "k-means++":
            init_c = kmeans_plusplus(
                key, pts, k,
                weights=None if buf_w is None else jnp.asarray(buf_w))
        else:
            init_c = random_init(key, pts, k)

        g = self._resolved_groups()
        groups = group_centroids(init_c, g)
        self._groups_np = np.asarray(jax.device_get(groups))
        self._groups = groups
        self._g = g
        self._members, self._gsize = _engine.build_group_tables(
            self._groups_np, g)

        if self.tune != "off":
            # adopt the tuned engine configuration for this batch shape
            # (B = first batch's size): capacity-lattice floor, chunk,
            # and the group-gather crossover of the per-batch passes.
            # Explicit constructor arguments keep precedence.
            from .. import tune as _tune
            cfg = _tune.lookup(n=self._buffer[0][1].shape[0], k=k,
                               d=int(buf.shape[1]))
            if cfg is not None:
                if not self._explicit_min_bucket:
                    self.min_bucket = int(cfg.min_cap)
                if not self._explicit_chunk:
                    self.chunk = int(cfg.chunk)
                self._ggf = int(cfg.group_gather_factor)
        self._centroids = init_c
        self._counts = jnp.zeros((k,), jnp.float32)
        self._ledger = DriftLedger(k, g)
        self._since_hit = np.zeros((k,), np.int64)
        self._shards_seen: set = set()
        self._far: list = []              # [(ub, point)] reseed reservoir

        replay, self._buffer, self._buffered = self._buffer, [], 0
        for sid, batch, w in replay:
            self._step(batch, sid, w)

    # -- the per-batch step ------------------------------------------------

    def partial_fit(self, points, shard_id=None,
                    sample_weight=None) -> "StreamingKMeans":
        """One mini-batch update. ``shard_id`` (hashable) keys the bound
        cache: pass it whenever the same points will be presented again
        (epochs over a :class:`~repro.data.PointStream` do this
        automatically) so carried bounds can skip the distance work.

        ``sample_weight``: optional (B,) per-point weights — they enter
        the batch sums/counts (the EMA's effective per-centroid mass)
        and the EWA batch-cost estimate; bounds and filter decisions
        are weight-independent, so the bound cache works unchanged."""
        pts = np.asarray(points, np.float32)
        if pts.ndim != 2 or pts.shape[0] == 0:
            raise ValueError(f"expected a non-empty (B, D) batch, got "
                             f"shape {pts.shape}")
        w = None if sample_weight is None else \
            np.asarray(sample_weight, np.float32)
        if w is not None and w.shape != (pts.shape[0],):
            raise ValueError(f"sample_weight shape {w.shape} does not "
                             f"match batch shape {pts.shape}")
        if not self.initialized:
            self._buffer.append((shard_id, pts, w))
            self._buffered += len(pts)
            self.stats_.init_batches += 1
            size = self.init_size or 2 * self.n_clusters
            if self._buffered >= max(size, self.n_clusters):
                self._initialize()
            return self
        self._step(pts, shard_id, w)
        return self

    def _shard_put(self, arr, spec):
        from jax.sharding import NamedSharding, PartitionSpec as P
        return jax.device_put(arr, NamedSharding(self.mesh, P(*spec)))

    def _sharded_update_fn(self, cap_n: int, cap_g: int, weighted: bool):
        from ..core import distributed as _dist
        key = (cap_n, cap_g, weighted)
        fn = self._sharded_updates.get(key)
        if fn is None:
            fn = _dist.make_stream_update_sharded(
                self.mesh, self.mesh_axes, k=self.n_clusters,
                n_groups=self._g, cap_n=cap_n, cap_g=cap_g,
                chunk=self.chunk, group_gather_factor=self._ggf,
                weighted=weighted)
            self._sharded_updates[key] = fn
        return fn

    def _local_core(self, cap_n: int, cap_g: int) -> PassCore:
        """The single-device streaming step's pass core at one
        (cap_n, cap_g) bucket — the same PassCore the batch and
        distributed drivers instantiate."""
        return PassCore(backend="compact", k=self.n_clusters,
                        n_groups=self._g, cap_n=cap_n, cap_g=cap_g,
                        chunk=self.chunk,
                        group_gather_factor=self._ggf)

    def _step(self, pts_np: np.ndarray, sid, w_np=None) -> None:
        t0 = time.perf_counter()
        b = pts_np.shape[0]
        g = self._g
        k = self.n_clusters
        st = self.stats_
        ax = self.mesh_axes

        entry = self._cache.get(sid) if sid is not None else None
        if entry is not None:
            slack = float(np.max(self._ledger.group - entry.gdrift_snap))
            if slack > self.drift_reset_factor * max(entry.ub_scale, 1e-12):
                # bounds still VALID but vacuous — recompute from scratch
                self._cache.drop(sid)
                st.drift_resets += 1
                entry = None

        # distributed step: pad the global batch to the shard lattice
        # with sentinel rows (assignment K drops out of the psum'd
        # sums; ub=0 / lb=inf keeps them filtered — zero cost)
        sharded = self.mesh is not None
        pad = (-b) % self._n_shards if sharded else 0
        if pad:
            pts = jnp.asarray(np.concatenate(
                [pts_np, np.zeros((pad, pts_np.shape[1]), np.float32)], 0))
        else:
            pts = jnp.asarray(pts_np)
        w = None
        if w_np is not None:
            w = jnp.asarray(np.concatenate(
                [w_np, np.zeros((pad,), np.float32)], 0) if pad else w_np)
        bp = b + pad
        shard_b = bp // self._n_shards if sharded else b

        def _padded(real, fill):
            if not pad:
                return real
            shape = (pad,) + real.shape[1:]
            return np.concatenate(
                [real, np.full(shape, fill, real.dtype)], 0)

        tightened = 0.0
        if entry is not None:
            st.cache_hits += 1
            ub_i, lb_i = inflate_bounds(entry, self._ledger.centroid,
                                        self._ledger.group)
            assign = jnp.asarray(_padded(
                entry.assignments.astype(np.int32), k))
            ub_i = jnp.asarray(_padded(ub_i, 0.0))
            lb_d = jnp.asarray(_padded(lb_i, np.inf))
            if sharded:
                if self._sharded_bounds is None:
                    from ..core import distributed as _dist
                    self._sharded_bounds = _dist.make_stream_bounds_sharded(
                        self.mesh, ax)
                ub_t, need, n_cand, n_tight = self._sharded_bounds(
                    self._shard_put(pts, (ax, None)),
                    self._shard_put(self._centroids, (None, None)),
                    self._shard_put(assign, (ax,)),
                    self._shard_put(ub_i, (ax,)),
                    self._shard_put(lb_d, (ax, None)))
            else:
                ub_t, need, n_cand, n_tight = _engine.stream_bounds(
                    pts, self._centroids, assign, ub_i, lb_d)
            # sharded: n_cand is the pmax'd PER-SHARD candidate count —
            # exactly what the static per-shard capacity must cover
            n_cand = int(n_cand)
            tightened = float(n_tight)
            gmax_guess = max(int(entry.gmax), 1)
        else:
            st.cache_misses += 1
            assign = jnp.asarray(_padded(np.zeros((b,), np.int32), k))
            ub_t = jnp.asarray(_padded(
                np.full((b,), np.inf, np.float32), 0.0))
            lb_d = jnp.asarray(_padded(
                np.zeros((b, g), np.float32), np.inf))
            need = jnp.asarray(_padded(np.ones((b,), bool), False))
            n_cand = shard_b if sharded else b
            gmax_guess = g

        # pow2 capacity lattice (cap_n >= candidate count is a hard
        # correctness requirement of the compact pass; cap_g is a guess
        # the pass spills past safely). Sharded: capacities are
        # PER-SHARD — sized from the worst shard's candidate count.
        cap_n = min(_bucket_cap(max(n_cand, 1),
                                min(self.min_bucket, shard_b), shard_b),
                    shard_b)
        cap_g = _bucket_cap(gmax_guess, 1, g)
        if sharded:
            upd = self._sharded_update_fn(cap_n, cap_g, w is not None)
            args = [self._shard_put(pts, (ax, None)),
                    self._shard_put(self._centroids, (None, None)),
                    self._shard_put(self._counts, (None,)),
                    self._shard_put(jnp.float32(self.decay), ()),
                    self._shard_put(self._groups, (None,)),
                    self._shard_put(self._members, (None, None)),
                    self._shard_put(self._gsize, (None,)),
                    self._shard_put(assign, (ax,)),
                    self._shard_put(ub_t, (ax,)),
                    self._shard_put(lb_d, (ax, None)),
                    self._shard_put(need, (ax,))]
            if w is not None:
                args.append(self._shard_put(w, (ax,)))
            out = upd(*args)
            st.sharded_batches += 1
        else:
            out = _engine.stream_step(
                pts, self._centroids, self._counts,
                jnp.float32(self.decay), self._groups, self._members,
                self._gsize, assign, ub_t, lb_d, need, w,
                core=self._local_core(cap_n, cap_g))
        self._centroids, self._counts = out.centroids, out.counts
        if self.chaos_hook is not None:
            self.chaos_hook(self, sid)

        (nas_np, ub_np, lb_np, pairs, gmax, drift_np, gdrift_np,
         bcounts_np, bcost) = jax.device_get(
            (out.assignments, out.ub, out.lb, out.pairs, out.gmax,
             out.drift, out.gdrift, out.batch_counts, out.batch_cost))
        if pad:
            nas_np, ub_np, lb_np = nas_np[:b], ub_np[:b], lb_np[:b]
        self._ledger.add(drift_np.astype(np.float64),
                         gdrift_np.astype(np.float64))

        st.batches += 1
        st.points_seen += b
        st.distance_evals += float(pairs) + tightened
        # EWA cost per unit of sample mass (== per point when unweighted)
        mass = b if w_np is None else max(float(w_np.sum()), 1e-12)
        per_pt = float(bcost) / mass
        self.ewa_inertia_ = per_pt if self.ewa_inertia_ is None else \
            (1 - self._ewa_alpha) * self.ewa_inertia_ \
            + self._ewa_alpha * per_pt
        self._labels_last = nas_np

        if sid is not None:
            self._cache.put(sid, ShardBounds(
                assignments=nas_np, ub=ub_np, lb=lb_np,
                ub_off=self._ledger.centroid[nas_np],
                gdrift_snap=self._ledger.group.copy(),
                gmax=max(int(gmax), 1),
                ub_scale=float(np.mean(ub_np))))

        if sid is not None:
            self._shards_seen.add(sid)
        self._since_hit = np.where(bcounts_np > 0, 0, self._since_hit + 1)
        self._push_far(pts_np, ub_np)
        self._maybe_reseed()

        if self._serve_index is not None and \
                st.batches % self._publish_every == 0:
            # continuous refresh: the serving index swaps in this
            # batch's committed centroids. The cumulative drift ledger
            # rides along so the index can decide table rebuild vs
            # reuse; serving never blocks (the swap is one reference).
            self._serve_index.publish(
                self._centroids, cum_drift=self._ledger.centroid)

        if self._obs is not None:
            # the step's device_get above already blocked, so this
            # wall-clock covers the real device work of the batch
            dt = time.perf_counter() - t0
            self._publish_batch(b=b, dt=dt, sid=sid, n_cand=n_cand,
                                pairs=float(pairs) + tightened,
                                hit=entry is not None)

    def _publish_batch(self, *, b, dt, sid, n_cand, pairs, hit) -> None:
        """Per-batch metrics publication (``obs=`` enabled only)."""
        reg = self._obs.resolve_registry()
        st = self.stats_
        reg.counter("stream_batches_total", "mini-batches processed").inc()
        reg.counter("stream_points_total", "points processed").inc(b)
        reg.histogram("stream_batch_seconds", "per-batch wall-clock",
                      ).observe(dt)
        reg.gauge("stream_points_per_s",
                  "last batch's throughput").set(b / max(dt, 1e-9))
        reg.gauge("stream_drift_magnitude",
                  "cumulative drift-ledger centroid magnitude").set(
            float(self._ledger.centroid.sum()))
        reg.gauge("stream_cache_hits", "bound-cache hits").set(
            st.cache_hits)
        reg.gauge("stream_cache_misses", "bound-cache misses").set(
            st.cache_misses)
        reg.gauge("stream_reseeds", "dead-centroid reseeds").set(
            st.reseeds)
        reg.gauge("stream_ewa_inertia", "EWA per-point batch cost").set(
            self.ewa_inertia_ or 0.0)
        reg.log_event("stream_batch", batch=st.batches, size=b,
                      seconds=dt, shard=sid, n_cand=int(n_cand),
                      pairs=pairs, cache_hit=bool(hit),
                      reseeds=st.reseeds,
                      drift=float(self._ledger.centroid.sum()))

    # -- dead-centroid re-seeding ------------------------------------------

    def _push_far(self, pts_np: np.ndarray, ub_np: np.ndarray,
                  keep: int = 2, cap: int = 64) -> None:
        """Reservoir of far points (largest distance-to-assigned): the
        reseed candidates. O(B) per batch, no extra distance work."""
        order = np.argsort(ub_np)[-keep:]
        for i in order:
            if np.isfinite(ub_np[i]):
                self._far.append((float(ub_np[i]), pts_np[i].copy()))
        self._far.sort(key=lambda t: -t[0])
        del self._far[cap:]

    def _maybe_reseed(self, per_batch: int = 2) -> None:
        # patience in EPOCHS: a centroid is dead only after going
        # unfed for reseed_patience full passes over the shards seen
        # so far (a raw batch count would kill live centroids whose
        # shard arrives late in a long epoch)
        patience = self.reseed_patience * max(len(self._shards_seen), 1)
        dead = np.nonzero(self._since_hit >= patience)[0]
        for c in dead[:per_batch]:
            if not self._far:
                break
            _, p = self._far.pop(0)
            old = np.asarray(jax.device_get(self._centroids[c]))
            self._centroids = self._centroids.at[c].set(jnp.asarray(p))
            self._counts = self._counts.at[c].set(1.0)
            # a reseed is just a big drift: cached bounds stay valid
            self._ledger.add_reseed(int(c), float(np.linalg.norm(p - old)),
                                    int(self._groups_np[c]))
            self._since_hit[c] = 0
            self.stats_.reseeds += 1

    # -- checkpoint / restore ----------------------------------------------

    _CKPT_FORMAT = "skm-stream-state-v1"

    def _pack_state(self):
        """Snapshot the FULL stream state as (leaves, meta).

        Every mutable host array is COPIED here (the ledger and
        ``_since_hit`` are mutated in place by later steps), so the
        snapshot is safe to hand to an async checkpoint writer. The
        fixed leaf head is [centroids, counts, ledger_centroid,
        ledger_group, since_hit, groups, labels_last, far_ub, far_pts];
        each cached shard appends [assignments, ub, lb, ub_off,
        gdrift_snap] in LRU order, with its id + scalars in
        ``meta['cache']``. The float64 ledger stays float64 end to end
        (npz round-trips bits exactly; restore never device_puts it)."""
        self._require_fitted()
        d = int(self._centroids.shape[1])
        labels = self._labels_last
        far_ub = np.asarray([u for u, _ in self._far], np.float64)
        far_pts = (np.stack([p for _, p in self._far]).astype(np.float32)
                   if self._far else np.zeros((0, d), np.float32))
        leaves = [
            np.asarray(jax.device_get(self._centroids), np.float32),
            np.asarray(jax.device_get(self._counts), np.float32),
            self._ledger.centroid.copy(),
            self._ledger.group.copy(),
            self._since_hit.copy(),
            np.array(self._groups_np),
            (np.zeros((0,), np.int32) if labels is None
             else np.array(labels)),
            far_ub, far_pts,
        ]
        cache_meta = []
        for sid in list(self._cache._d.keys()):       # LRU order
            e = self._cache._d[sid]
            leaves += [np.array(e.assignments), np.array(e.ub),
                       np.array(e.lb), np.array(e.ub_off),
                       np.array(e.gdrift_snap)]
            cache_meta.append({"sid": sid, "gmax": int(e.gmax),
                               "ub_scale": float(e.ub_scale)})
        meta = {
            "format": self._CKPT_FORMAT,
            "config": {
                "n_clusters": self.n_clusters, "n_groups": self._g,
                "init": self.init, "decay": self.decay,
                "init_size": self.init_size, "seed": self.seed,
                "min_bucket": self.min_bucket, "chunk": self.chunk,
                "ggf": self._ggf,
                "reseed_patience": self.reseed_patience,
                "drift_reset_factor": self.drift_reset_factor,
                "max_cached_shards": self._cache.max_shards,
            },
            "has_labels": labels is not None,
            "ewa_inertia": self.ewa_inertia_,
            "stats": self.stats_.to_dict(),
            "shards_seen": sorted(self._shards_seen),
            "cache": cache_meta,
            "n_shards_at_save": self._n_shards,
        }
        return leaves, meta

    def save(self, ckpt_dir, step: int, *, async_: bool = False):
        """Checkpoint the full stream state (see :meth:`_pack_state`)
        through :func:`repro.checkpoint.save_checkpoint` — atomic
        publish, LATEST pointer, optional async writer thread (returned
        so callers can ``join``). ``step`` is the stream-schedule index
        the state corresponds to (the resilient driver's global batch
        counter) — restore hands it back so replay knows where to
        resume."""
        from ..checkpoint.checkpoint import save_checkpoint
        leaves, meta = self._pack_state()
        t = save_checkpoint(ckpt_dir, step, leaves, async_=async_,
                            meta=meta)
        self.stats_.ckpt_saves += 1
        return t

    def _install(self, manifest: dict, leaves: list) -> None:
        """Overwrite ALL live state from a checkpoint's arrays. The
        new-mesh (elastic) path needs nothing special: cached bounds
        are stored UNPADDED per shard, capacities and shard padding are
        re-derived per batch from the CURRENT mesh, and the sharded
        step/bounds programs are compiled lazily — so a checkpoint from
        a 2-shard run restores into a 4-shard (or single-device) run
        with every cached bound still valid."""
        meta = manifest.get("meta") or {}
        if meta.get("format") != self._CKPT_FORMAT:
            raise ValueError(
                f"not a stream-state checkpoint (format="
                f"{meta.get('format')!r})")
        cfg = meta["config"]
        if cfg["n_clusters"] != self.n_clusters:
            raise ValueError(
                f"checkpoint has n_clusters={cfg['n_clusters']}, "
                f"estimator has {self.n_clusters}")
        (cent, counts, led_c, led_g, since, groups, labels,
         far_ub, far_pts) = leaves[:9]
        k, g = self.n_clusters, int(cfg["n_groups"])

        self._centroids = jnp.asarray(cent)
        self._counts = jnp.asarray(counts)
        self._g = g
        self._groups_np = np.array(groups)
        self._groups = jnp.asarray(self._groups_np.astype(np.int32))
        self._members, self._gsize = _engine.build_group_tables(
            self._groups_np, g)
        self._ledger = DriftLedger(k, g)
        self._ledger.centroid[:] = led_c
        self._ledger.group[:] = led_g
        self._since_hit = np.array(since)
        self._labels_last = np.array(labels) if meta["has_labels"] else None
        self._far = [(float(u), far_pts[i].copy())
                     for i, u in enumerate(far_ub)]
        self._shards_seen = set(meta["shards_seen"])
        self.ewa_inertia_ = meta["ewa_inertia"]
        known = {f.name for f in dataclasses.fields(StreamStats)}
        self.stats_ = StreamStats(**{kk: v for kk, v in
                                     meta["stats"].items() if kk in known})
        # the tuned engine configuration was resolved at cold start;
        # adopt the checkpointed values so the restored run compiles
        # the exact same per-batch programs
        self.min_bucket = int(cfg["min_bucket"])
        self.chunk = int(cfg["chunk"])
        self._ggf = int(cfg["ggf"])
        self._cache = BoundCache(int(cfg["max_cached_shards"]))
        off = 9
        for ce in meta["cache"]:
            a, ub, lb, ub_off, gsnap = leaves[off:off + 5]
            off += 5
            self._cache.put(ce["sid"], ShardBounds(
                assignments=np.array(a), ub=np.array(ub),
                lb=np.array(lb), ub_off=np.array(ub_off),
                gdrift_snap=np.array(gsnap), gmax=int(ce["gmax"]),
                ub_scale=float(ce["ub_scale"])))
        self._buffer, self._buffered = [], 0
        # mesh-dependent compiled programs are stale on elastic restore
        self._sharded_bounds = None
        self._sharded_updates = {}

    def restore_state(self, ckpt_dir, *, step: int | None = None,
                      fallback: bool = True) -> int:
        """Restore this estimator's full stream state from the latest
        (or given) checkpoint under ``ckpt_dir``; returns the
        checkpoint's stream-schedule step so the caller can replay the
        deterministic stream from there. ``fallback=True`` walks back
        to the newest COMPLETE save when the latest is corrupt or
        partial (see :func:`repro.checkpoint.load_checkpoint_arrays`)."""
        from ..checkpoint.checkpoint import load_checkpoint_arrays
        got_step, manifest, leaves = load_checkpoint_arrays(
            ckpt_dir, step=step, fallback=fallback)
        self._install(manifest, leaves)
        self.stats_.restores += 1
        return got_step

    @classmethod
    def restore(cls, ckpt_dir, *, step: int | None = None, mesh=None,
                mesh_axes=("data",), obs=None, fallback: bool = True):
        """Build a fresh estimator from a checkpoint — the ELASTIC
        entry point: pass the NEW (grown/shrunk/absent) ``mesh`` and
        the state re-pads into it on the next batch. Returns
        ``(estimator, step)``."""
        from ..checkpoint.checkpoint import load_checkpoint_arrays
        got_step, manifest, leaves = load_checkpoint_arrays(
            ckpt_dir, step=step, fallback=fallback)
        meta = manifest.get("meta") or {}
        if meta.get("format") != cls._CKPT_FORMAT:
            raise ValueError(
                f"not a stream-state checkpoint (format="
                f"{meta.get('format')!r})")
        cfg = meta["config"]
        skm = cls(cfg["n_clusters"], n_groups=cfg["n_groups"],
                  init=cfg["init"], decay=cfg["decay"],
                  init_size=cfg["init_size"], seed=cfg["seed"],
                  min_bucket=cfg["min_bucket"], chunk=cfg["chunk"],
                  max_cached_shards=cfg["max_cached_shards"],
                  reseed_patience=cfg["reseed_patience"],
                  drift_reset_factor=cfg["drift_reset_factor"],
                  tune="off", mesh=mesh, mesh_axes=mesh_axes, obs=obs)
        skm._install(manifest, leaves)
        skm.stats_.restores += 1
        return skm, got_step

    def reset_state(self) -> None:
        """Drop ALL learned state, back to the just-constructed cold
        start (the restore path when a failure lands before the first
        checkpoint: replaying the deterministic stream from step 0
        through a reset estimator reproduces the original cold start
        bit-for-bit)."""
        self._centroids = None
        self._counts = None
        self._ledger = None
        self._labels_last = None
        self._buffer, self._buffered = [], 0
        self._cache = BoundCache(self._cache.max_shards)
        self._sharded_bounds = None
        self._sharded_updates = {}
        self.stats_ = StreamStats()
        self.ewa_inertia_ = None

    def adopt_centroids(self, centroids, counts=None) -> None:
        """Warm handover: replace the live centroids with externally
        supplied ones (e.g. from a peer run's checkpoint) WITHOUT
        discarding the bound cache — each centroid's jump ``||Δc||``
        enters the :class:`DriftLedger` exactly like a reseed, so every
        cached bound stays a true triangle-inequality bound against the
        adopted centroids."""
        self._require_fitted()
        new = np.asarray(centroids, np.float32)
        old = np.asarray(jax.device_get(self._centroids))
        if new.shape != old.shape:
            raise ValueError(f"adopted centroids shape {new.shape} != "
                             f"{old.shape}")
        jump = np.linalg.norm(new - old, axis=-1).astype(np.float64)
        gjump = np.zeros((self._g,), np.float64)
        np.maximum.at(gjump, self._groups_np.astype(np.int64), jump)
        self._ledger.add(jump, gjump)
        self._centroids = jnp.asarray(new)
        if counts is not None:
            self._counts = jnp.asarray(np.asarray(counts, np.float32))

    # -- stream driving ----------------------------------------------------

    def attach_index(self, index, every: int = 1) -> "StreamingKMeans":
        """Continuous refresh: publish committed centroids into a
        :class:`repro.serve.CentroidIndex` every ``every`` batches.

        The publish happens AFTER the host-side commit of each batch
        (ledger updated, cache stored), so a served snapshot is always
        a state the fit actually passed through — and carries the
        cumulative drift ledger, letting the index reuse group tables
        across small-drift epochs. Detach with ``attach_index(None)``.
        """
        self._serve_index = index
        self._publish_every = max(int(every), 1)
        if index is not None and self.initialized:
            index.publish(self._centroids,
                          cum_drift=self._ledger.centroid)
        return self

    def fit_stream(self, source, epochs: int = 1,
                   max_batches: int | None = None, *,
                   resilient: bool = False, ckpt_dir=None,
                   ckpt_every: int = 8, injector=None, watchdog=None,
                   max_restarts: int = 8,
                   async_ckpt: bool = True) -> "StreamingKMeans":
        """Drive :meth:`partial_fit` over a stream source.

        ``source`` may be a :class:`repro.data.PointStream` (shard ids
        carried automatically; ``epochs`` replays it), a sequence of
        arrays or ``(shard_id, array)`` pairs, or any iterable of
        those / of ``{'points': ..., 'shard_id': ...,
        'sample_weight': ...}`` dicts (the ``PrefetchingLoader``
        protocol; ``sample_weight`` optional). Generators are consumed
        once regardless of ``epochs``. Short streams that never reach
        ``init_size`` are flushed into an init at the end.

        ``resilient=True`` (requires ``ckpt_dir`` and a deterministic
        ``global_batch``-protocol source such as ``PointStream``)
        drives the fit through the fault-tolerant runtime instead: the
        full stream state checkpoints every ``ckpt_every`` batches
        (atomic, async by default), any failure restores the latest
        complete checkpoint (falling back past corrupt ones) and
        REPLAYS the deterministic stream from the checkpointed batch
        index — landing on centroids bit-identical to an uninterrupted
        run (see :mod:`repro.streaming.resilient` and
        ``docs/fault_tolerance.md``). ``injector``/``watchdog`` are
        the :mod:`repro.runtime.fault_tolerance` chaos/straggler
        hooks."""
        if resilient:
            from .resilient import fit_stream_resilient
            if ckpt_dir is None:
                raise ValueError("resilient=True requires ckpt_dir")
            return fit_stream_resilient(
                self, source, ckpt_dir=ckpt_dir, epochs=epochs,
                max_batches=max_batches, ckpt_every=ckpt_every,
                injector=injector, watchdog=watchdog,
                max_restarts=max_restarts, async_ckpt=async_ckpt)
        seen = 0
        for sid, pts, w in self._iter_source(source, epochs):
            self.partial_fit(pts, shard_id=sid, sample_weight=w)
            seen += 1
            if max_batches is not None and seen >= max_batches:
                break
        if not self.initialized and self._buffer:
            self._initialize()
        return self

    @staticmethod
    def _coerce(item):
        if isinstance(item, dict):
            sid = item.get("shard_id")
            w = item.get("sample_weight")
            return (None if sid is None else int(sid)), \
                np.asarray(item["points"]), \
                (None if w is None else np.asarray(w, np.float32))
        if isinstance(item, tuple) and len(item) == 2:
            sid, pts = item
            if isinstance(pts, dict):       # PrefetchingLoader: (step, batch)
                return StreamingKMeans._coerce(pts)
            return sid, np.asarray(pts), None
        return None, np.asarray(item), None

    def _iter_source(self, source, epochs):
        if hasattr(source, "batches"):      # PointStream
            for sid, pts in source.batches(epochs):
                yield sid, pts, None
            return
        import collections.abc
        reiterable = isinstance(source, collections.abc.Sequence)
        for _ in range(max(int(epochs), 1)):
            for item in source:
                yield self._coerce(item)
            if not reiterable:
                return

    # -- accessors ---------------------------------------------------------

    @property
    def cluster_centers_(self) -> np.ndarray:
        self._require_fitted()
        return np.asarray(jax.device_get(self._centroids))

    @property
    def counts_(self) -> np.ndarray:
        """Decayed effective per-centroid counts (the EMA weights)."""
        self._require_fitted()
        return np.asarray(jax.device_get(self._counts))

    @property
    def labels_(self) -> np.ndarray:
        """Assignments of the most recent batch."""
        self._require_fitted()
        return self._labels_last

    def predict(self, points) -> np.ndarray:
        """Tiled exact nearest-centroid assignment through the PassCore
        candidate pass (``engine.assign``) — no (N, K) matrix, bounded
        per-tile working set, under the same tuned crossover as the
        fitted passes."""
        self._require_fitted()
        labels, _ = _engine.assign(
            np.asarray(points, np.float32), self._centroids,
            groups=self._groups, members=self._members, gsize=self._gsize,
            chunk=self.chunk, group_gather_factor=self._ggf)
        return np.asarray(jax.device_get(labels))

    def inertia_of(self, points, sample_weight=None) -> float:
        """Exact (optionally weighted) sum of squared distances of
        ``points`` to their nearest current centroid (through the tiled
        engine pass — no (N, K) matrix)."""
        self._require_fitted()
        _, dists = _engine.assign(
            np.asarray(points, np.float32), self._centroids,
            groups=self._groups, members=self._members, gsize=self._gsize,
            chunk=self.chunk, group_gather_factor=self._ggf)
        d2 = dists * dists
        if sample_weight is not None:
            d2 = d2 * jnp.asarray(np.asarray(sample_weight, np.float32))
        return float(jnp.sum(d2))
