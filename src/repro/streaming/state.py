"""Streaming-fit state: drift ledger, per-shard bound cache, stats.

The streaming fit's work-efficiency comes from *carrying*
triangle-inequality bounds across mini-batches instead of recomputing
them per batch. The pieces here make that sound:

* :class:`DriftLedger` — cumulative per-centroid / per-group drift
  since stream start (host float64, so sums of fp32 drifts over
  millions of batches stay exact enough);
* :class:`ShardBounds` — the filter state of one shard, valid against
  the centroids at store time, plus the ledger snapshot taken then;
* :func:`inflate_bounds` — re-validates a cached entry against the
  CURRENT centroids by the triangle inequality: every upper bound
  grows by its assigned centroid's accumulated drift, every group
  lower bound shrinks by its group's accumulated max drift. The
  property test in ``tests/test_streaming.py`` checks exactly this
  invariant under arbitrary drift sequences.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict

import numpy as np


@dataclasses.dataclass
class StreamStats:
    """Convergence / work diagnostics for a streaming fit."""
    batches: int = 0
    points_seen: int = 0
    distance_evals: float = 0.0
    cache_hits: int = 0
    cache_misses: int = 0
    drift_resets: int = 0
    reseeds: int = 0
    init_batches: int = 0     # batches buffered for the cold-start init
    sharded_batches: int = 0  # batches run through the distributed step
    ckpt_saves: int = 0       # stream-state checkpoints written
    restores: int = 0         # stream-state restores (failure or resume)
    replayed_batches: int = 0  # batches re-run after a restore

    def to_dict(self) -> dict:
        """JSON-serializable view (event logs / benchmark payloads)."""
        return dataclasses.asdict(self)


@dataclasses.dataclass
class ShardBounds:
    """Cached filter state for one shard. ``ub``/``lb`` are valid
    against the centroids at store time; ``ub_off``/``gdrift_snap``
    snapshot the :class:`DriftLedger` then, so :func:`inflate_bounds`
    can re-validate later without any per-step history."""
    assignments: np.ndarray   # (B,) int32
    ub: np.ndarray            # (B,) fp32
    lb: np.ndarray            # (B, G) fp32
    ub_off: np.ndarray        # (B,) f64 ledger.centroid[assignments] at store
    gdrift_snap: np.ndarray   # (G,) f64 ledger.group at store
    gmax: int                 # surviving-group high-water at store time
    ub_scale: float           # mean ub at store (drift-reset yardstick)


def inflate_bounds(entry: ShardBounds, cum_drift: np.ndarray,
                   cum_gdrift: np.ndarray):
    """Re-validate cached bounds against the current centroids.

    ``d(x, c_a_now) <= d(x, c_a_then) + ||c_a moved|| <= ub + delta``
    and symmetrically for the group lower bounds, where the deltas are
    the ledger accumulation since the entry's snapshot. Returns fp32
    ``(ub, lb)`` ready for :func:`repro.core.engine.stream_bounds`.
    """
    ub = entry.ub + (cum_drift[entry.assignments] - entry.ub_off)
    lb = np.maximum(
        entry.lb - (cum_gdrift - entry.gdrift_snap)[None, :], 0.0)
    return ub.astype(np.float32), lb.astype(np.float32)


class DriftLedger:
    """Cumulative centroid movement since stream start."""

    def __init__(self, k: int, n_groups: int):
        self.centroid = np.zeros((k,), np.float64)   # sum of per-step drift
        self.group = np.zeros((n_groups,), np.float64)

    def add(self, drift: np.ndarray, gdrift: np.ndarray) -> None:
        self.centroid += drift
        self.group += gdrift

    def add_reseed(self, c: int, dist: float, group: int) -> None:
        """A re-seeded centroid is just a very large drift — bounds
        cached before the reseed stay valid through the ledger."""
        self.centroid[c] += dist
        self.group[group] += dist


class BoundCache:
    """LRU map shard-id -> :class:`ShardBounds` (bounded so a long tail
    of one-shot shards cannot grow host memory without limit)."""

    def __init__(self, max_shards: int = 256):
        self.max_shards = max_shards
        self._d: OrderedDict = OrderedDict()

    def get(self, sid) -> ShardBounds | None:
        entry = self._d.get(sid)
        if entry is not None:
            self._d.move_to_end(sid)
        return entry

    def put(self, sid, entry: ShardBounds) -> None:
        self._d[sid] = entry
        self._d.move_to_end(sid)
        while len(self._d) > self.max_shards:
            self._d.popitem(last=False)

    def drop(self, sid) -> None:
        self._d.pop(sid, None)

    def __len__(self) -> int:
        return len(self._d)
