"""Problem signatures: the autotuner's cache key.

A tuned :class:`~repro.core.engine.EngineConfig` is only transferable
between problems that stress the engine the same way, which KPynq's
cost model says is (platform, N, K, D): the platform picks the
backend/realisation, N the capacity lattice, K the candidate-pass GEMM
minor dim, D the arithmetic intensity of every distance. N is bucketed
to its power-of-two ceiling — the engine's own capacity lattice is
pow2, so two problems in the same bucket compile the same programs.

The DISTRIBUTED engine adds a shard-count dimension (``shards=``): a
per-shard capacity ladder tuned for one shard of an S-way fit is not
interchangeable with the single-device config for the same per-shard N
(the sharded body pays psum latency per iteration, which moves the
bucket/crossover trade-offs), so sharded winners are keyed separately
as ``...|sS``. ``shards=1`` (the default) keeps the original key format
— existing caches stay valid.
"""
from __future__ import annotations

import jax


def pow2_bucket(n: int) -> int:
    """Smallest power of two >= n (n >= 1)."""
    return 1 << (max(int(n), 1) - 1).bit_length()


def signature(n: int, k: int, d: int, platform: str | None = None,
              shards: int = 1) -> str:
    """Cache key for a (platform, N, K, D[, shards]) problem class.
    ``n`` is the PER-SHARD point count when ``shards > 1``."""
    if platform is None:
        platform = jax.default_backend()
    sig = f"{platform}|n{pow2_bucket(n)}|k{int(k)}|d{int(d)}"
    if int(shards) > 1:
        sig += f"|s{int(shards)}"
    return sig
