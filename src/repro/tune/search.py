"""Measured configuration search: a hill-climb / grid hybrid.

The engine's knobs interact too much for closed-form choice (AccD's
core observation: distance-kernel configuration must be *searched* per
shape, not hand-picked), but the space is small and benign enough that
exhaustive grid search is waste. The hybrid here:

1. **Backend grid** — measure one default-knob candidate per viable
   backend (``lloyd`` / ``compact`` / ``pallas`` on TPU). The dense
   Lloyd GEMM is always in the running: for filter-hostile shapes
   (tiny N*K, or K so large the group filter never bites) *not
   filtering* is the fastest correct engine, and making that a
   first-class tuning outcome is what keeps ``mean_speedup >= 1``
   honest.
2. **Coordinate hill-climb** — from the winning backend, sweep each of
   its knobs over a small lattice, adopting strict improvements, for
   up to ``max_rounds`` rounds (stop early when a round finds
   nothing). Deterministic given a deterministic ``measure``.

Measurements go through an injectable ``measure(config) -> seconds``
so tests can drive the search with a stub; the default measures real
wall-clock (best-of-``repeats`` of a full ``engine.fit``, compile
excluded by a warmup call).

Correctness is never at stake: every candidate produces bit-identical
assignments/inertia (``tests/test_tune.py`` asserts it), so the search
can be aggressive and its cache can be stale, wrong-platform, or
hand-edited without risking results.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from ..core.engine import EngineConfig
from ..obs.trace import span
from .cache import TuneCache, default_cache
from .signature import signature

# knob -> candidate lattice. Kept small on purpose: each point is a
# compile + a few timed fits.
KNOB_LATTICE = {
    "min_cap": (128, 256, 512, 1024),
    "chunk": (1024, 2048, 4096),
    "group_gather_factor": (2, 4, 8),
    "down_n": (0, 2, 4),
    "down_g": (0, 2, 4, 8),
    "refresh_in_pass": (False, True),
    "tile_n": (128, 256, 512),
}

# which knobs matter per backend (lloyd has none: its only knob IS
# being lloyd). refresh_in_pass first: it changes the capacity regime
# the other knobs are then refined under.
BACKEND_KNOBS = {
    "compact": ("refresh_in_pass", "min_cap", "chunk",
                "group_gather_factor", "down_n", "down_g"),
    "pallas": ("tile_n", "min_cap"),
    "oracle": (),
    "lloyd": (),
}


def candidate_backends(platform: str) -> tuple:
    if platform == "tpu":
        return ("pallas", "compact", "lloyd")
    return ("compact", "lloyd")


def _best_of(run, repeats):
    """Best-of-``repeats`` wall-clock of ``run`` (warmup excluded);
    sub-ms runs keep sampling until ~50ms of timing has accumulated
    (capped) so one noisy sample cannot flip a backend decision."""
    run()                                        # compile + warm caches
    best = float("inf")
    done = 0
    spent = 0.0
    while done < repeats or (spent < 0.05 and done < 4 * repeats):
        t0 = time.perf_counter()
        run()
        dt = time.perf_counter() - t0
        best = min(best, dt)
        spent += dt
        done += 1
    return best


def timing_measure(points, init_c, *, n_groups=None, max_iters=50,
                   tol=1e-4, repeats=3):
    """Default measurement: best-of-``repeats`` wall-clock of a full
    ``engine.fit`` under the candidate config (warmup excluded)."""
    from ..core import engine

    def measure(cfg: EngineConfig) -> float:
        def run():
            r = engine.fit(points, init_c, n_groups=n_groups,
                           max_iters=max_iters, tol=tol, config=cfg,
                           tune="off")
            jax.block_until_ready(jax.tree.leaves(r))
        return _best_of(run, repeats)

    return measure


def sharded_timing_measure(shard_points, init_c, shards: int, *,
                           mesh=None, axes=("data",), n_groups=None,
                           max_iters=50, tol=1e-4, repeats=3):
    """Measurement hook for the DISTRIBUTED signatures (``...|sS``):
    best-of-``repeats`` wall-clock of ``distributed_yinyang(backend=
    "compact", config=cfg)`` — the unified driver under ``shard_map``
    — so sharded winners are produced by sharded measurement, not the
    single-device fallback.

    ``shard_points`` is ONE SHARD's worth of points (the unit the
    ``...|sS`` signature is keyed on); the global problem is its
    ``shards``-fold tiling, which keeps the per-shard shapes (and thus
    the compiled programs) exactly those of a real S-way fit.
    ``mesh=None`` builds a 1-D mesh over the first ``shards`` local
    devices (raises when the runtime has fewer — force them with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=S`` on CPU).
    """
    import numpy as np

    from ..core.distributed import distributed_yinyang

    if mesh is None:
        devs = jax.devices()
        if len(devs) < shards:
            raise ValueError(
                f"sharded_timing_measure needs >= {shards} devices, "
                f"found {len(devs)}; on CPU force them with "
                f"XLA_FLAGS=--xla_force_host_platform_device_count="
                f"{shards}")
        mesh = jax.sharding.Mesh(np.asarray(devs[:shards]), ("data",))
        axes = ("data",)
    axes = tuple(axes)
    global_pts = jnp.concatenate([jnp.asarray(shard_points)] * shards,
                                 axis=0)

    def measure(cfg: EngineConfig) -> float:
        def run():
            r = distributed_yinyang(
                global_pts, init_c, mesh, axes=axes, n_groups=n_groups,
                max_iters=max_iters, tol=tol, backend="compact",
                config=cfg, tune="off")
            jax.block_until_ready(jax.tree.leaves(r))
        return _best_of(run, repeats)

    return measure


def autotune(points, init_c, *, n_groups=None, max_iters: int = 50,
             tol: float = 1e-4, cache: TuneCache | None = None,
             measure=None, repeats: int = 3, max_rounds: int = 2,
             max_measurements: int = 32, platform: str | None = None,
             shards: int = 1, mesh=None, axes=("data",),
             verbose: bool = False) -> EngineConfig:
    """Search the engine configuration space for this problem and
    persist the winner under its (platform, N, K, D[, shards])
    signature.

    Returns the winning :class:`EngineConfig`. ``measure`` overrides
    the wall-clock measurement (tests use a stub); ``max_measurements``
    bounds the total number of distinct configs measured.

    ``shards > 1`` tunes the DISTRIBUTED key (``points`` then being one
    shard's worth): the default measure is
    :func:`sharded_timing_measure` — the unified driver under
    ``shard_map`` over ``mesh`` (built from the first ``shards`` local
    devices when None), so ``...|sS`` winners come from sharded
    measurement. The backend grid is skipped there (the sharded body
    realises its own compact pass; Lloyd is not a sharded candidate)
    and the climb runs over the compact knobs.
    """
    if platform is None:
        platform = jax.default_backend()
    n, d = points.shape
    k = init_c.shape[0]
    sig = signature(n, k, d, platform, shards=shards)
    if cache is None:
        cache = default_cache()
    if measure is None:
        if shards > 1:
            measure = sharded_timing_measure(
                points, init_c, shards, mesh=mesh, axes=axes,
                n_groups=n_groups, max_iters=max_iters, tol=tol,
                repeats=repeats)
        else:
            measure = timing_measure(points, init_c, n_groups=n_groups,
                                     max_iters=max_iters, tol=tol,
                                     repeats=repeats)

    memo: dict = {}

    def cost(cfg: EngineConfig) -> float:
        key = tuple(sorted(cfg.to_dict().items()))
        if key not in memo:
            if len(memo) >= max_measurements:
                return float("inf")
            with span("tune.measure", sig=sig,
                      backend=cfg.backend) as fields:
                memo[key] = float(measure(cfg))
                fields["best_s"] = memo[key]
            if verbose:
                print(f"tune[{sig}] {cfg.backend} "
                      f"{memo[key] * 1e3:8.2f}ms  {cfg.to_dict()}")
        return memo[key]

    # phase 1: backend grid at default knobs. Lloyd is the bar to
    # clear, not a climb candidate (it has no knobs) — so climb the
    # best FILTERED backend even when the default-knob seed loses to
    # Lloyd, and only settle the backend question after the climb.
    # (Deciding at seed stage threw away configs that beat Lloyd only
    # after tuning — exactly the medium-shape regime this issue is
    # about.) Sharded keys have no backend question: the shard_map body
    # is always the ladder'd compact pass, so only its knobs climb.
    if shards > 1:
        lloyd_cost = None
        best = EngineConfig(backend="compact")
        best_cost = cost(best)
        climb_knobs = BACKEND_KNOBS["compact"]
    else:
        lloyd_cost = cost(EngineConfig(backend="lloyd"))
        engine_seeds = [EngineConfig(backend=b)
                        for b in candidate_backends(platform)
                        if b != "lloyd"]
        best = min(engine_seeds, key=cost)
        best_cost = cost(best)
        climb_knobs = BACKEND_KNOBS[best.backend]

    # phase 2: coordinate hill-climb over the filtered winner's knobs
    for _ in range(max_rounds):
        improved = False
        for knob in climb_knobs:
            for val in KNOB_LATTICE[knob]:
                if val == getattr(best, knob):
                    continue
                cand = best.replace(**{knob: val})
                c = cost(cand)
                if c < best_cost:
                    best, best_cost = cand, c
                    improved = True
        if not improved:
            break

    # phase 3: the backend decision, made on tuned-vs-lloyd terms
    if lloyd_cost is not None and lloyd_cost < best_cost:
        best, best_cost = EngineConfig(backend="lloyd"), lloyd_cost

    extra = {} if lloyd_cost is None else {"lloyd_ms": lloyd_cost * 1e3}
    cache.store(sig, best, ms=best_cost * 1e3, measured=len(memo),
                n=int(n), k=int(k), d=int(d), shards=int(shards),
                **extra)
    if verbose:
        vs = "" if lloyd_cost is None else \
            f" vs lloyd {lloyd_cost * 1e3:.2f}ms"
        print(f"tune[{sig}] winner: {best.backend} "
              f"{best_cost * 1e3:.2f}ms{vs} ({len(memo)} configs)")
    return best


def get_or_tune(points, init_c, *, n_groups=None, max_iters: int = 50,
                tol: float = 1e-4, cache: TuneCache | None = None,
                **tune_kw) -> EngineConfig:
    """Cached-or-searched config for this problem (``fit(tune='force')``
    lands here): return the cache hit if present, else run
    :func:`autotune` and return (and persist) the winner."""
    if cache is None:
        cache = default_cache()
    n, d = points.shape
    k = init_c.shape[0]
    hit = cache.lookup(signature(n, k, d))
    if hit is not None:
        return hit
    return autotune(points, init_c, n_groups=n_groups,
                    max_iters=max_iters, tol=tol, cache=cache, **tune_kw)
