"""Serve-path knob family: the serving hot loop's tuned choices.

The serving subsystem (:mod:`repro.serve`) has its own configuration
axis, disjoint from :class:`~repro.core.engine.EngineConfig`: the
batched-assign backend and its internal tile, the micro-batching
bucket lattice, and the drift threshold at which the centroid index
rebuilds its group tables. The right values depend on (platform, K, D)
only — the serve path never sees a fixed N (batches are whatever the
queue coalesces), so N is not part of the signature.

Entries live in the same :class:`~repro.tune.cache.TuneCache` as the
engine's, under ``serve|``-prefixed signatures, so one cache file (and
one ``$REPRO_KMEANS_TUNE_CACHE`` override) covers both families.
Like engine tuning, serve tuning is pure wall-clock: every backend is
exact (``tests/test_serve.py`` asserts oracle parity), so a stale
cache can never corrupt labels.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from .cache import TuneCache, default_cache


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Knobs of the serving hot loop (see ``docs/serving.md``).

    backend : batched-assign realisation — ``"fused"`` (dense GEMM +
        min-trick reduction; the CPU winner), ``"grouped"`` (PassCore
        compact pass over the group tables), ``"pallas"`` (block-skip
        kernel).
    chunk : `lax.map` tile inside one batch; keeps the per-tile
        (chunk, K) distance block cache-resident.
    max_batch : coalescing ceiling = largest padding bucket. Requests
        larger than this are split by ``ServeEngine.submit``.
    min_bucket : smallest padding bucket; ragged batches pad up to the
        next pow2 in [min_bucket, max_batch], so the compiled-program
        set is the bucket lattice, nothing else.
    max_wait_us : optional linger after the first request of a batch,
        trading p50 latency for batch fill (0 = serve greedily).
    rebuild_threshold : max cumulative per-centroid drift (relative to
        the typical centroid norm) the index tolerates before a publish
        rebuilds the group tables instead of reusing them. Reuse is
        always exact — stale grouping only costs pruning efficiency.
    """
    backend: str = "fused"
    chunk: int = 1024
    max_batch: int = 8192
    min_bucket: int = 256
    max_wait_us: int = 0
    rebuild_threshold: float = 0.05

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "ServeConfig":
        """Tolerant inverse of :meth:`to_dict` (unknown keys from a
        newer writer are ignored)."""
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in names})

    def replace(self, **kw) -> "ServeConfig":
        return dataclasses.replace(self, **kw)


DEFAULT_SERVE_CONFIG = ServeConfig()


def serve_signature(k: int, d: int, platform: str | None = None) -> str:
    """Cache key of the serve knob family — ``serve|platform|kK|dD``."""
    if platform is None:
        platform = jax.default_backend()
    return f"serve|{platform}|k{int(k)}|d{int(d)}"


def lookup_serve(*, k: int, d: int, platform: str | None = None,
                 cache: TuneCache | None = None) -> ServeConfig | None:
    """Tuned serve config for a (platform, K, D) signature, or None."""
    if cache is None:
        cache = default_cache()
    e = cache.entry(serve_signature(k, d, platform))
    if not e or "config" not in e:
        return None
    return ServeConfig.from_dict(e["config"])


def autotune_serve(*, k: int, d: int, backends=None,
                   chunks=(512, 1024, 2048), max_batch: int = 8192,
                   repeats: int = 5, cache: TuneCache | None = None,
                   store: bool = True) -> ServeConfig:
    """Measure the serve backend x chunk grid on a synthetic full
    bucket and persist the winner.

    Small by design: the serve grid is (backend, chunk) at ONE bucket
    shape — the bucket lattice itself is a shape policy, not a timing
    choice, and every candidate computes identical labels so best-of
    wall-clock is the whole objective.
    """
    from ..core import engine as _engine
    from ..core.distances import row_norms_sq

    if backends is None:
        backends = ["fused", "grouped"]
        if jax.default_backend() == "tpu":
            backends.append("pallas")
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((max_batch, d)).astype(np.float32))
    centroids = jnp.asarray(rng.standard_normal((k, d)).astype(np.float32))
    c2 = row_norms_sq(centroids)
    groups, members, gsize = _engine.build_assign_tables(centroids)
    shape = (k, int(gsize.shape[0]))

    best_cfg, best_t = DEFAULT_SERVE_CONFIG, float("inf")
    for backend in backends:
        for chunk in chunks:
            fn = _engine.make_serve_assign(
                shape, backend=backend, chunk=int(chunk),
                interpret=jax.default_backend() != "tpu")
            try:
                jax.block_until_ready(
                    fn(q, centroids, c2, groups, members, gsize))
            except Exception:       # backend unavailable on this platform
                continue
            t_best = float("inf")
            for _ in range(repeats):
                t0 = time.perf_counter()
                jax.block_until_ready(
                    fn(q, centroids, c2, groups, members, gsize))
                t_best = min(t_best, time.perf_counter() - t0)
            if t_best < best_t:
                best_t = t_best
                best_cfg = ServeConfig(backend=backend, chunk=int(chunk),
                                       max_batch=int(max_batch))
    if store:
        if cache is None:
            cache = default_cache()
        cache.store(serve_signature(k, d), best_cfg,
                    points_per_sec=max_batch / max(best_t, 1e-12),
                    measured_ms=best_t * 1e3)
    return best_cfg
