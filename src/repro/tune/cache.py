"""Persistent per-(platform, N, K, D) tuning cache.

One JSON file maps problem signatures to their measured-best
:class:`~repro.core.engine.EngineConfig` plus the measurements that
justified it. Default location: ``~/.cache/repro_kmeans_tune.json``;
override with the ``REPRO_KMEANS_TUNE_CACHE`` environment variable or
an explicit ``TuneCache(path=...)``.

The cache is loaded once per process and written through on every
store, so ``benchmarks/run.py --tune`` and the fits that follow in the
same process always agree. A corrupt or version-mismatched file is
treated as empty (tuning is always safe to redo — it can never change
results, only wall-clock).
"""
from __future__ import annotations

import json
import os
import tempfile

from ..core.engine import EngineConfig

ENV_VAR = "REPRO_KMEANS_TUNE_CACHE"
VERSION = 1


def default_path() -> str:
    env = os.environ.get(ENV_VAR)
    if env:
        return os.path.expanduser(env)
    return os.path.join(os.path.expanduser("~"), ".cache",
                        "repro_kmeans_tune.json")


class TuneCache:
    """Disk-backed signature -> tuned-config map (see module docstring).

    ``path=None`` resolves :func:`default_path` at construction time
    (so the env var is honoured per instance, not per import).
    """

    def __init__(self, path: str | None = None):
        self.path = path if path is not None else default_path()
        self._entries: dict | None = None        # lazy-loaded

    # -- persistence -------------------------------------------------------

    def load(self, reload: bool = False) -> dict:
        if self._entries is not None and not reload:
            return self._entries
        self._entries = {}
        try:
            with open(self.path) as fh:
                payload = json.load(fh)
            if isinstance(payload, dict) and \
                    payload.get("version") == VERSION:
                self._entries = dict(payload.get("entries", {}))
        except (FileNotFoundError, ValueError, OSError):
            pass
        return self._entries

    def save(self) -> None:
        payload = {"version": VERSION, "entries": self.load()}
        os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
        # atomic-ish write: never leave a torn JSON behind for the next
        # process to choke on
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(self.path) or ".",
                                   suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as fh:
                json.dump(payload, fh, indent=2, sort_keys=True)
            os.replace(tmp, self.path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    # -- access ------------------------------------------------------------

    def entry(self, sig: str) -> dict | None:
        """Raw cache record (config + measurements) or None."""
        return self.load().get(sig)

    def lookup(self, sig: str) -> EngineConfig | None:
        e = self.entry(sig)
        if not e or "config" not in e:
            return None
        return EngineConfig.from_dict(e["config"])

    def store(self, sig: str, config: EngineConfig, **meta) -> None:
        self.load()[sig] = {"config": config.to_dict(), **meta}
        self.save()

    def drop(self, sig: str) -> None:
        if self.load().pop(sig, None) is not None:
            self.save()

    def clear(self) -> None:
        self._entries = {}
        self.save()

    def signatures(self) -> list:
        return sorted(self.load())


_default: TuneCache | None = None


def default_cache() -> TuneCache:
    """Process-wide cache singleton (what ``engine.fit`` consults)."""
    global _default
    if _default is None:
        _default = TuneCache()
    return _default


def set_default_cache(cache: TuneCache | str | None) -> TuneCache:
    """Replace the process-wide cache (tests / benchmark harnesses).
    Accepts a TuneCache, a path, or None to re-resolve the default."""
    global _default
    if isinstance(cache, str):
        cache = TuneCache(cache)
    _default = cache
    return default_cache()
