"""Per-(platform, N, K, D) autotuning for the K-means engine.

The engine's fixed heuristics (``tile_n``, ``min_cap``, the
group-gather crossover, the capacity-downshift hysteresis, the
Lloyd-vs-filter backend choice) are measured choices whose right
values depend on the problem signature. This package searches that
configuration space (:func:`autotune` — a backend grid + coordinate
hill-climb, see :mod:`repro.tune.search`), persists winners to a disk
cache (:class:`TuneCache`, ``~/.cache/repro_kmeans_tune.json`` or
``$REPRO_KMEANS_TUNE_CACHE``), and answers lookups from
``engine.fit(tune=...)`` / ``KMeans(tune=...)`` /
``StreamingKMeans(tune=...)``.

Tuning is pure wall-clock: every configuration produces bit-identical
assignments and inertia (asserted by ``tests/test_tune.py``), so a
stale or foreign cache can never corrupt results.
"""
from __future__ import annotations

from ..core.engine import DEFAULT_CONFIG, EngineConfig
from .cache import (ENV_VAR, TuneCache, default_cache, default_path,
                    set_default_cache)
from .search import (autotune, get_or_tune, sharded_timing_measure,
                     timing_measure)
from .serve import (DEFAULT_SERVE_CONFIG, ServeConfig, autotune_serve,
                    lookup_serve, serve_signature)
from .signature import pow2_bucket, signature

__all__ = [
    "EngineConfig", "DEFAULT_CONFIG", "TuneCache", "default_cache",
    "default_path", "set_default_cache", "autotune", "get_or_tune",
    "timing_measure", "sharded_timing_measure", "signature",
    "pow2_bucket", "lookup", "ENV_VAR",
    "ServeConfig", "DEFAULT_SERVE_CONFIG", "serve_signature",
    "lookup_serve", "autotune_serve",
]


def lookup(*, n: int, k: int, d: int, platform: str | None = None,
           shards: int = 1,
           cache: TuneCache | None = None) -> EngineConfig | None:
    """Tuned config for a problem signature, or None on a cache miss.
    This is the (cheap, in-memory after first disk read) call on
    ``engine.fit``'s hot path when ``tune != "off"``. ``shards > 1``
    queries the distributed-engine key (``n`` = per-shard points)."""
    if cache is None:
        cache = default_cache()
    return cache.lookup(signature(n, k, d, platform, shards=shards))
