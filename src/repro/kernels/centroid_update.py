"""Pallas TPU kernel: centroid accumulation as a one-hot MXU matmul.

Scatter-add is hostile to the TPU; the native formulation is
  sums   = onehot(assign)^T @ points        (K, N) x (N, D)
  counts = onehot(assign)^T @ 1
The kernel tiles N and builds the (tile_n, K) one-hot on the fly from
the int32 assignment tile (broadcasted_iota compare — no HBM one-hot
materialisation), then accumulates (K, D) partial sums across the N
grid dimension in the revisited output block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _centroid_update_kernel(a_ref, x_ref, sums_ref, counts_ref, *, k: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        sums_ref[...] = jnp.zeros_like(sums_ref)
        counts_ref[...] = jnp.zeros_like(counts_ref)

    a = a_ref[...]                                          # (tn, 1) int32
    x = x_ref[...].astype(jnp.float32)                      # (tn, D)
    ks = jax.lax.broadcasted_iota(jnp.int32, (a.shape[0], k), 1)
    onehot = (a == ks).astype(jnp.float32)                  # (tn, K)
    sums_ref[...] += jax.lax.dot_general(
        onehot, x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                 # (K, D)
    counts_ref[...] += jnp.sum(onehot, axis=0, keepdims=True).T  # (K, 1)


@functools.partial(jax.jit, static_argnames=("k", "tile_n", "interpret"))
def centroid_update(points: jnp.ndarray, assignments: jnp.ndarray, *,
                    k: int, tile_n: int = 512, interpret: bool = False):
    """(N, D), (N,) int32 -> ((K, D) sums fp32, (K,) counts fp32)."""
    n, d = points.shape
    n_pad = (-n) % tile_n
    xp = jnp.pad(points, ((0, n_pad), (0, 0)))
    # padded rows get assignment -1: matches no centroid, contributes 0
    ap = jnp.pad(assignments.astype(jnp.int32), (0, n_pad),
                 constant_values=-1)[:, None]
    grid = (xp.shape[0] // tile_n,)
    sums, counts = pl.pallas_call(
        functools.partial(_centroid_update_kernel, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_n, 1), lambda i: (i, 0)),
            pl.BlockSpec((tile_n, d), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((k, d), lambda i: (0, 0)),
            pl.BlockSpec((k, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, d), jnp.float32),
            jax.ShapeDtypeStruct((k, 1), jnp.float32),
        ],
        interpret=interpret,
    )(ap, xp)
    return sums, counts[:, 0]
