"""Pallas TPU kernel: fused Multi-level Filter + Distance Calculator.

This is the heart of the KPynq adaptation. The FPGA design lets a
filtered point bypass the distance pipeline entirely; a TPU cannot
branch per point, so work-efficiency is realised at BLOCK granularity:

  grid = (N/tile_n points) x (K/tile_k centroid blocks)
  block_mask[i, j] = does ANY point in tile i still need ANY centroid
                     group overlapping block j (from the group-level
                     lower bounds)?

The kernel body runs the (tile_n x D x tile_k) MXU matmul **only under
``@pl.when(block_mask)``** — a skipped block costs one SMEM scalar read,
no VMEM traffic for c, no MXU issue. Filter hit-rates are spatially
correlated once clusters stabilise, so block-skip recovers most of the
per-point saving (measured in benchmarks/filter_efficiency.py).

The running (min, argmin) lives in the output blocks, revisited across
the K grid dimension (sequential "arbitrary" semantics on TPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _filtered_assign_kernel(mask_ref, x_ref, x2_ref, c_ref, c2_ref,
                            best_ref, idx_ref, *, tile_k: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        best_ref[...] = jnp.full_like(best_ref, jnp.inf)
        idx_ref[...] = jnp.full_like(idx_ref, -1)

    @pl.when(mask_ref[0, 0] != 0)
    def _compute():
        x = x_ref[...].astype(jnp.float32)                 # (tn, D)
        c = c_ref[...].astype(jnp.float32)                 # (tk, D)
        # squared norms arrive precomputed (cached by the caller across
        # iterations) — the kernel only does the cross term
        x2 = x2_ref[...]                                   # (tn, 1)
        c2 = c2_ref[...].reshape(1, tile_k)                # (1, tk)
        cross = jax.lax.dot_general(
            x, c, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        d2 = jnp.maximum(x2 - 2.0 * cross + c2, 0.0)        # (tn, tk)
        local_min = jnp.min(d2, axis=1, keepdims=True)      # (tn, 1)
        local_arg = jnp.argmin(d2, axis=1).astype(jnp.int32)[:, None]
        local_arg = local_arg + j * tile_k
        better = local_min < best_ref[...]
        idx_ref[...] = jnp.where(better, local_arg, idx_ref[...])
        best_ref[...] = jnp.minimum(best_ref[...], local_min)


@functools.partial(jax.jit,
                   static_argnames=("tile_n", "tile_k", "interpret"))
def filtered_assign(x: jnp.ndarray, c: jnp.ndarray,
                    block_mask: jnp.ndarray, *,
                    tile_n: int = 256, tile_k: int = 128,
                    interpret: bool = False,
                    x2: jnp.ndarray | None = None,
                    c2: jnp.ndarray | None = None):
    """Block-skipping nearest-centroid search.

    x: (N, D); c: (K, D); block_mask: (ceil(N/tile_n), ceil(K/tile_k))
    bool/int — True where the block must be computed. ``x2`` (N,) /
    ``c2`` (K,): optional precomputed squared norms (callers that fit
    iteratively cache them across calls; ``None`` computes locally —
    identical results).
    Returns (min_sq_dist (N,) fp32, argmin (N,) int32); fully-skipped
    rows yield (+inf, -1).
    """
    n, d = x.shape
    k = c.shape[0]
    n_pad = (-n) % tile_n
    k_pad = (-k) % tile_k
    xp = jnp.pad(x, ((0, n_pad), (0, 0)))
    # pad centroids with +BIG so they never win the argmin
    cp = jnp.pad(c, ((0, k_pad), (0, 0)),
                 constant_values=jnp.asarray(1e15, c.dtype))
    gn, gk = xp.shape[0] // tile_n, cp.shape[0] // tile_k
    mask = block_mask.astype(jnp.int32).reshape(gn, gk)
    if x2 is None:
        x2 = jnp.sum(x.astype(jnp.float32) ** 2, axis=-1)
    x2p = jnp.pad(x2.astype(jnp.float32), (0, n_pad))[:, None]
    if c2 is None:
        c2p = jnp.sum(cp.astype(jnp.float32) ** 2, axis=-1)
    else:
        # pad norms must match the +BIG pad rows so they never win
        c2p = jnp.pad(c2.astype(jnp.float32), (0, k_pad),
                      constant_values=jnp.float32(1e30) * d)
    c2p = c2p[:, None]                                      # (Kp, 1)

    best, idx = pl.pallas_call(
        functools.partial(_filtered_assign_kernel, tile_k=tile_k),
        grid=(gn, gk),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),      # mask scalar
            pl.BlockSpec((tile_n, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_n, 1), lambda i, j: (i, 0)),  # x2 tile
            pl.BlockSpec((tile_k, d), lambda i, j: (j, 0)),
            pl.BlockSpec((tile_k, 1), lambda i, j: (j, 0)),  # c2 tile
        ],
        out_specs=[
            pl.BlockSpec((tile_n, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_n, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((xp.shape[0], 1), jnp.float32),
            jax.ShapeDtypeStruct((xp.shape[0], 1), jnp.int32),
        ],
        interpret=interpret,
    )(mask, xp, x2p, cp, c2p)
    return best[:n, 0], idx[:n, 0]
