"""Pallas TPU kernel: tiled pairwise squared distances (Distance Calculator).

KPynq's Distance Calculator PE array maps to the MXU: the -2*x@c^T term
is a (tile_n, D) x (D, tile_k) matmul per grid cell; the norm terms are
cheap VPU reductions fused into the same block. HBM->VMEM streaming is
expressed with BlockSpec (the TPU analogue of the paper's DMA stream).

Tile defaults are MXU-aligned (multiples of 128 in the lane dim, 8 in
sublanes); D is carried whole per block — K-means dimensionality
(<= a few hundred) fits VMEM comfortably:
  VMEM/block = tile_n*D + tile_k*D + tile_n*tile_k floats
  (256*256 + 128*256 + 256*128) * 4B = 0.5 MiB << 16 MiB VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dist_kernel(x_ref, c_ref, out_ref):
    x = x_ref[...].astype(jnp.float32)                     # (tn, D)
    c = c_ref[...].astype(jnp.float32)                     # (tk, D)
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)            # (tn, 1)
    c2 = jnp.sum(c * c, axis=-1)[None, :]                  # (1, tk)
    cross = jax.lax.dot_general(
        x, c, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                # MXU, fp32 acc
    out_ref[...] = jnp.maximum(x2 - 2.0 * cross + c2, 0.0)


@functools.partial(jax.jit, static_argnames=("tile_n", "tile_k", "interpret"))
def pairwise_sq_dists(x: jnp.ndarray, c: jnp.ndarray, *,
                      tile_n: int = 256, tile_k: int = 128,
                      interpret: bool = False) -> jnp.ndarray:
    """(N, D) x (K, D) -> (N, K) squared distances. Pads N/K to tiles."""
    n, d = x.shape
    k = c.shape[0]
    n_pad = (-n) % tile_n
    k_pad = (-k) % tile_k
    xp = jnp.pad(x, ((0, n_pad), (0, 0)))
    cp = jnp.pad(c, ((0, k_pad), (0, 0)))
    grid = (xp.shape[0] // tile_n, cp.shape[0] // tile_k)
    out = pl.pallas_call(
        _dist_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_n, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_k, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tile_n, tile_k), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], cp.shape[0]),
                                       jnp.float32),
        interpret=interpret,
    )(xp, cp)
    return out[:n, :k]
