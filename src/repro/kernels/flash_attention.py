"""Pallas TPU kernel: causal flash attention (online softmax).

Why it exists in a K-means paper's framework: the roofline baselines
(EXPERIMENTS.md §Roofline) show the attention archs' memory term is
dominated by HBM-materialised (S, S) score tensors — XLA cannot fuse
matmul->softmax->matmul chains into VMEM. This kernel is the standard
fix: tile q into (block_q) rows and stream kv in (block_k) columns,
keeping scores, the running max m, and the running denominator l in
VMEM scratch the whole time. Score traffic against HBM: ZERO.

Grid: (batch*heads, S/block_q, S/block_k) — kv index innermost
("arbitrary" semantics) so the output block is revisited and the
softmax renormalisation accumulates in place. Causality skips whole
kv blocks above the diagonal via @pl.when (the same block-granular
work-skipping idea as the KPynq filter kernel).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, block_q: int, block_k: int, scale: float):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal block filter: kv block strictly above the diagonal -> skip
    @pl.when(kj * block_k <= qi * block_q + block_q - 1)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                   # (bq, d)
        k = k_ref[0].astype(jnp.float32)                   # (bk, d)
        v = v_ref[0].astype(jnp.float32)                   # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * scale                                       # (bq, bk)
        # in-block causal mask
        q_pos = qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        k_pos = kj * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        s = jnp.where(q_pos >= k_pos, s, -jnp.inf)

        m_prev = m_ref[...]                                 # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        # guard fully-masked rows (m_new = -inf)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe)
        p = jnp.where(jnp.isfinite(s), p, 0.0)
        alpha = jnp.where(jnp.isfinite(m_prev),
                          jnp.exp(m_prev - m_safe), 0.0)    # (bq, 1)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kj == pl.num_programs(2) - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_q", "block_k", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    block_q: int = 256, block_k: int = 256,
                    interpret: bool = False) -> jnp.ndarray:
    """Causal attention. q, k, v: (B, H, S, D) -> (B, H, S, D).
    GQA callers broadcast kv heads before the call (zero-copy view)."""
    b, h, s, d = q.shape
    assert k.shape == v.shape == (b, h, s, d)
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0
    scale = 1.0 / math.sqrt(d)
    bh = b * h
    qf = q.reshape(bh, s, d)
    kf = k.reshape(bh, s, d)
    vf = v.reshape(bh, s, d)
    grid = (bh, s // block_q, s // block_k)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, block_q=block_q,
                          block_k=block_k, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b_, i, j: (b_, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b_, i, j: (b_, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b_, i, j: (b_, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b_, i, j: (b_, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),    # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),    # running denom l
            pltpu.VMEM((block_q, d), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, s, d)
