"""Pallas TPU kernel: fused SSD intra-chunk pass (Mamba-2).

The §Roofline baselines show the SSM archs' memory term is dominated by
HBM-materialised (Q, Q) intra-chunk tensors (scores, decay, their
product) — XLA cannot fuse dot -> mask/exp -> dot. This kernel computes

    y_intra = ((C B^T) ∘ tril(exp(cum_i - cum_j))) x̄

for one (batch·chunk, head) grid cell entirely in VMEM: the (Q, Q)
scores/decay never touch HBM. The inter-chunk recurrence (tiny
(N, P) states) stays in jnp (associative_scan — see models/mamba.py).

VMEM per cell (Q=128, N=128, P=64 fp32):
  C,B: 2*Q*N*4 = 128 KiB; x: Q*P*4 = 32 KiB; scores: Q*Q*4 = 64 KiB
  — comfortably inside a v5e core's 16 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_intra_kernel(c_ref, b_ref, x_ref, cum_ref, out_ref):
    c = c_ref[0].astype(jnp.float32)                       # (Q, N)
    b = b_ref[0].astype(jnp.float32)                       # (Q, N)
    x = x_ref[0].astype(jnp.float32)                       # (Q, P)
    cum = cum_ref[0].astype(jnp.float32)                   # (Q, 1)
    q = c.shape[0]
    scores = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    diff = cum - cum.reshape(1, q)                         # cum_i - cum_j
    i_pos = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    j_pos = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    decay = jnp.where(i_pos >= j_pos, jnp.exp(diff), 0.0)
    out_ref[0] = jax.lax.dot_general(
        scores * decay, x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_intra(c: jnp.ndarray, b: jnp.ndarray, x: jnp.ndarray,
              cum: jnp.ndarray, *, interpret: bool = False) -> jnp.ndarray:
    """Fused intra-chunk SSD.

    c, b: (G_cells, Q, N) — per (batch*chunk*head) cell state matrices
    x:    (G_cells, Q, P) — discretised inputs
    cum:  (G_cells, Q)    — within-chunk cumulative log-decay
    returns y_intra: (G_cells, Q, P) fp32.
    """
    g, q, n = c.shape
    p = x.shape[-1]
    return pl.pallas_call(
        _ssd_intra_kernel,
        grid=(g,),
        in_specs=[
            pl.BlockSpec((1, q, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, q, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, q, p), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, q, 1), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, q, p), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((g, q, p), jnp.float32),
        interpret=interpret,
    )(c, b, x, cum[..., None])
