"""Pallas TPU kernels for KPynq (validated via interpret=True on CPU)."""
from .flash_attention import flash_attention
from .ssd_intra import ssd_intra
from .ops import (build_block_mask, build_group_block_mask,
                  centroid_update, compact_indices, filtered_assign,
                  filtered_assign_auto, grouped_assign, pairwise_sq_dists)

__all__ = ["pairwise_sq_dists", "filtered_assign", "centroid_update",
           "build_block_mask", "build_group_block_mask", "compact_indices",
           "filtered_assign_auto", "grouped_assign", "flash_attention",
           "ssd_intra"]
