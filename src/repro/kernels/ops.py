"""Jit'd public wrappers gluing the Pallas kernels to the algorithm layer.

``build_block_mask`` converts the algorithmic per-(point, group) filter
decisions into the block-granular skip mask the fused kernel consumes —
the exact point where KPynq's per-point pipeline bypass becomes the
TPU's block bypass. ``compact_indices`` is the beyond-paper stream-
compaction alternative (gather survivors into dense tiles).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .centroid_update import centroid_update
from .distance import pairwise_sq_dists
from .filtered_assign import filtered_assign
from .grouped_assign import grouped_assign

__all__ = ["pairwise_sq_dists", "filtered_assign", "centroid_update",
           "build_block_mask", "build_group_block_mask", "compact_indices",
           "filtered_assign_auto", "grouped_assign"]


@functools.partial(jax.jit, static_argnames=("tile_n", "tile_k"))
def build_block_mask(group_need: jnp.ndarray, groups: jnp.ndarray,
                     *, tile_n: int, tile_k: int) -> jnp.ndarray:
    """(N, G) per-point-per-group need + (K,) group ids ->
    (ceil(N/tile_n), ceil(K/tile_k)) bool block mask.

    block (i, j) is needed iff any point in tile i needs any group that
    owns a centroid in centroid-block j.
    """
    n, _ = group_need.shape
    k = groups.shape[0]
    cand = group_need[:, groups]                            # (N, K) bool
    n_pad, k_pad = (-n) % tile_n, (-k) % tile_k
    cand = jnp.pad(cand, ((0, n_pad), (0, k_pad)))
    gn, gk = cand.shape[0] // tile_n, cand.shape[1] // tile_k
    blocks = cand.reshape(gn, tile_n, gk, tile_k)
    return jnp.any(blocks, axis=(1, 3))


@functools.partial(jax.jit, static_argnames=("tile_n",))
def build_group_block_mask(group_need: jnp.ndarray, *,
                           tile_n: int) -> jnp.ndarray:
    """(N, G) per-point-per-group need -> (ceil(N/tile_n), G) bool mask
    for the group-granular kernel (``grouped_assign``): block (i, g) is
    live iff any point in tile i needs group g. Finer-grained than
    ``build_block_mask`` — a group IS a centroid block, so the
    group-level filter maps 1:1 onto skipped blocks."""
    n, g = group_need.shape
    n_pad = (-n) % tile_n
    padded = jnp.pad(group_need, ((0, n_pad), (0, 0)))
    return jnp.any(padded.reshape(-1, tile_n, g), axis=1)


@functools.partial(jax.jit, static_argnames=("capacity",))
def compact_indices(mask: jnp.ndarray, *, capacity: int):
    """Stream compaction: indices of True entries, padded to ``capacity``.

    Returns (idx (capacity,) int32 — invalid slots point at 0 —,
    valid (capacity,) bool, count scalar). Deterministic order.
    """
    n = mask.shape[0]
    pos = jnp.cumsum(mask.astype(jnp.int32)) - 1            # slot per hit
    count = jnp.sum(mask.astype(jnp.int32))
    src = jnp.arange(n, dtype=jnp.int32)
    slot = jnp.where(mask, pos, capacity)                   # misses -> OOB
    idx = jnp.zeros((capacity,), jnp.int32).at[slot].set(src, mode="drop")
    valid = jnp.arange(capacity) < jnp.minimum(count, capacity)
    return idx, valid, count


def filtered_assign_auto(x, c, group_need, groups, *,
                         tile_n: int = 256, tile_k: int = 128,
                         interpret: bool = False):
    """One call: algorithmic filter decisions -> block mask -> fused
    block-skip kernel. Returns (min_sq_dist, argmin, block_density)."""
    mask = build_block_mask(group_need, groups, tile_n=tile_n,
                            tile_k=tile_k)
    best, idx = filtered_assign(x, c, mask, tile_n=tile_n, tile_k=tile_k,
                                interpret=interpret)
    density = jnp.mean(mask.astype(jnp.float32))
    return best, idx, density
