"""Pallas TPU kernel: group-granular block-skip nearest-centroid search.

The original ``filtered_assign`` kernel skips (tile_n x tile_k) blocks
but only yields the global (min, argmin) — enough for Hamerly, not for
Yinyang, whose lower-bound refresh needs *per-group* minima. This
kernel makes the centroid grid dimension THE GROUP: the grid is
``(N/tile_n, G)``, each step loads one group's (Lmax-padded) centroid
bucket, and a skipped block is exactly one group-level filter decision
realised as skipped MXU work.

Per live block it maintains:

* the running global ``(min_sq_dist, argmin)`` across groups
  (sequential revisits over the minor grid axis, as in
  ``filtered_assign``), and
* per-(point, group) ``(min, argmin, second_min)`` — precisely the
  triple the engine needs to rebuild the Yinyang lower bound
  ``min_{c in g, c != assigned} d(x, c)`` without materialising any
  (N, K) distance matrix: the excluded centroid can only collide with
  the group argmin, in which case the second-min is the answer.

Centroids arrive pre-bucketed as ``c_grouped`` (G, Lmax, D) with a
parallel ``ids`` (G, Lmax) int32 table (-1 padding); padded slots are
masked to +inf inside the kernel so empty/ragged groups are exact.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _grouped_assign_kernel(mask_ref, x_ref, x2_ref, c_ref, c2_ref, ids_ref,
                           best_ref, idx_ref, gmin_ref, garg_ref, gmin2_ref,
                           *, lmax: int):
    g = pl.program_id(1)

    @pl.when(g == 0)
    def _init_global():
        best_ref[...] = jnp.full_like(best_ref, jnp.inf)
        idx_ref[...] = jnp.full_like(idx_ref, -1)

    # per-group outputs are visited exactly once; default = "skipped"
    gmin_ref[...] = jnp.full_like(gmin_ref, jnp.inf)
    garg_ref[...] = jnp.full_like(garg_ref, -1)
    gmin2_ref[...] = jnp.full_like(gmin2_ref, jnp.inf)

    @pl.when(mask_ref[0, 0] != 0)
    def _compute():
        x = x_ref[...].astype(jnp.float32)                  # (tn, D)
        c = c_ref[0].astype(jnp.float32)                    # (Lmax, D)
        ids = ids_ref[0]                                    # (Lmax,)
        # squared norms arrive precomputed (once per fit for x2, once
        # per iteration for c2) — the kernel only does the cross term
        x2 = x2_ref[...]                                    # (tn, 1)
        c2 = c2_ref[0][None, :]                             # (1, Lmax)
        cross = jax.lax.dot_general(
            x, c, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        d2 = jnp.maximum(x2 - 2.0 * cross + c2, 0.0)        # (tn, Lmax)
        d2 = jnp.where((ids >= 0)[None, :], d2, jnp.inf)

        min1 = jnp.min(d2, axis=1)                          # (tn,)
        arg_local = jnp.argmin(d2, axis=1)                  # (tn,)
        onehot = arg_local[:, None] == jax.lax.broadcasted_iota(
            jnp.int32, (1, lmax), 1)                        # (tn, Lmax)
        arg = jnp.sum(jnp.where(onehot, ids[None, :], 0), axis=1)
        min2 = jnp.min(jnp.where(onehot, jnp.inf, d2), axis=1)

        gmin_ref[...] = min1[:, None]
        garg_ref[...] = arg.astype(jnp.int32)[:, None]
        gmin2_ref[...] = min2[:, None]

        better = min1[:, None] < best_ref[...]
        idx_ref[...] = jnp.where(better, arg.astype(jnp.int32)[:, None],
                                 idx_ref[...])
        best_ref[...] = jnp.minimum(best_ref[...], min1[:, None])


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def grouped_assign(x: jnp.ndarray, c_grouped: jnp.ndarray,
                   ids: jnp.ndarray, block_mask: jnp.ndarray, *,
                   tile_n: int = 256, interpret: bool = False,
                   x2: jnp.ndarray | None = None,
                   c2g: jnp.ndarray | None = None):
    """Group-block-skipping nearest-centroid search with per-group stats.

    x: (N, D); c_grouped: (G, Lmax, D) group-bucketed centroids;
    ids: (G, Lmax) int32 original centroid index per slot (-1 = pad);
    block_mask: (ceil(N/tile_n), G) bool/int — True where the group
    must be scored for that point tile. ``x2`` (N,) / ``c2g``
    (G, Lmax): optional precomputed squared norms — the engine caches
    ``||x||^2`` once per fit and ``||c||^2`` once per iteration and
    passes them here so the kernel never recomputes them (``None``
    computes locally; identical results).

    Returns ``(best (N,) fp32 sq-dist, idx (N,) int32,
    gmin (N, G) fp32, garg (N, G) int32, gmin2 (N, G) fp32)``; skipped
    (tile, group) blocks read as (inf, -1, inf), fully-skipped rows as
    (inf, -1) globally.
    """
    n, d = x.shape
    g, lmax = ids.shape
    n_pad = (-n) % tile_n
    xp = jnp.pad(x, ((0, n_pad), (0, 0)))
    gn = xp.shape[0] // tile_n
    mask = block_mask.astype(jnp.int32).reshape(gn, g)
    if x2 is None:
        x2 = jnp.sum(x.astype(jnp.float32) ** 2, axis=-1)
    x2p = jnp.pad(x2.astype(jnp.float32), (0, n_pad))[:, None]  # (Np, 1)
    if c2g is None:
        c2g = jnp.sum(c_grouped.astype(jnp.float32) ** 2, axis=-1)
    c2g = c2g.astype(jnp.float32)                               # (G, Lmax)

    best, idx, gmin, garg, gmin2 = pl.pallas_call(
        functools.partial(_grouped_assign_kernel, lmax=lmax),
        grid=(gn, g),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),        # mask
            pl.BlockSpec((tile_n, d), lambda i, j: (i, 0)),   # x tile
            pl.BlockSpec((tile_n, 1), lambda i, j: (i, 0)),   # x2 tile
            pl.BlockSpec((1, lmax, d), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((1, lmax), lambda i, j: (j, 0)),     # c2
            pl.BlockSpec((1, lmax), lambda i, j: (j, 0)),     # ids
        ],
        out_specs=[
            pl.BlockSpec((tile_n, 1), lambda i, j: (i, 0)),   # best
            pl.BlockSpec((tile_n, 1), lambda i, j: (i, 0)),   # idx
            pl.BlockSpec((tile_n, 1), lambda i, j: (i, j)),   # gmin
            pl.BlockSpec((tile_n, 1), lambda i, j: (i, j)),   # garg
            pl.BlockSpec((tile_n, 1), lambda i, j: (i, j)),   # gmin2
        ],
        out_shape=[
            jax.ShapeDtypeStruct((xp.shape[0], 1), jnp.float32),
            jax.ShapeDtypeStruct((xp.shape[0], 1), jnp.int32),
            jax.ShapeDtypeStruct((xp.shape[0], g), jnp.float32),
            jax.ShapeDtypeStruct((xp.shape[0], g), jnp.int32),
            jax.ShapeDtypeStruct((xp.shape[0], g), jnp.float32),
        ],
        interpret=interpret,
    )(mask, xp, x2p, c_grouped.astype(jnp.float32), c2g,
      ids.astype(jnp.int32))
    return (best[:n, 0], idx[:n, 0], gmin[:n], garg[:n], gmin2[:n])
