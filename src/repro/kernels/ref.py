"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each function mirrors one kernel in this package with identical
signature and output semantics; tests sweep shapes/dtypes and
``assert_allclose`` kernel-vs-oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pairwise_sq_dists_ref(x: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """(N, D), (K, D) -> (N, K) squared distances, fp32 accumulate."""
    xf = x.astype(jnp.float32)
    cf = c.astype(jnp.float32)
    x2 = jnp.sum(xf * xf, axis=-1, keepdims=True)
    c2 = jnp.sum(cf * cf, axis=-1)
    return jnp.maximum(x2 - 2.0 * (xf @ cf.T) + c2[None, :], 0.0)


def filtered_assign_ref(x: jnp.ndarray, c: jnp.ndarray,
                        block_mask: jnp.ndarray,
                        tile_n: int, tile_k: int):
    """Block-skip argmin oracle.

    ``block_mask[i, j]`` (bool) says whether the distance block
    (points i*tile_n:(i+1)*tile_n) x (centroids j*tile_k:(j+1)*tile_k)
    must be computed. Skipped blocks contribute +inf.
    Returns (min_sq_dist (N,), argmin (N,) int32); rows whose every
    block is skipped return (+inf, -1).
    """
    n, k = x.shape[0], c.shape[0]
    d2 = pairwise_sq_dists_ref(x, c)
    mask_full = jnp.repeat(jnp.repeat(block_mask, tile_n, axis=0),
                           tile_k, axis=1)[:n, :k]
    d2 = jnp.where(mask_full, d2, jnp.inf)
    best = jnp.min(d2, axis=1)
    idx = jnp.where(jnp.isfinite(best), jnp.argmin(d2, axis=1), -1)
    return best, idx.astype(jnp.int32)


def grouped_assign_ref(x, c_grouped, ids, block_mask, tile_n: int):
    """Oracle for the group-granular block-skip kernel.

    Mirrors ``grouped_assign``: per (point, group) returns
    (min, argmin-id, second-min) of squared distances over the group's
    valid slots, +inf/-1 for skipped blocks and padded slots; global
    (best, idx) reduced over live groups only.
    """
    n = x.shape[0]
    g, lmax, _ = c_grouped.shape
    live = jnp.repeat(jnp.asarray(block_mask, bool), tile_n, axis=0)[:n]
    d2 = pairwise_sq_dists_ref(
        x, c_grouped.reshape(g * lmax, -1)).reshape(n, g, lmax)
    d2 = jnp.where((ids >= 0)[None], d2, jnp.inf)
    d2 = jnp.where(live[:, :, None], d2, jnp.inf)
    gmin = jnp.min(d2, axis=2)
    slot = jnp.argmin(d2, axis=2)
    garg = jnp.take_along_axis(jnp.broadcast_to(ids[None], d2.shape),
                               slot[..., None], 2)[..., 0]
    eye = slot[..., None] == jnp.arange(lmax)[None, None]
    gmin2 = jnp.min(jnp.where(eye, jnp.inf, d2), axis=2)
    best = jnp.min(gmin, axis=1)
    bg = jnp.argmin(gmin, axis=1)
    idx = jnp.where(jnp.isfinite(best),
                    jnp.take_along_axis(garg, bg[:, None], 1)[:, 0], -1)
    gmin = jnp.where(live, gmin, jnp.inf)
    garg = jnp.where(live, garg, -1)
    gmin2 = jnp.where(live, gmin2, jnp.inf)
    return (best, idx.astype(jnp.int32), gmin, garg.astype(jnp.int32),
            gmin2)


def centroid_update_ref(points: jnp.ndarray, assignments: jnp.ndarray,
                        k: int):
    """Segment sums + counts: (K, D) fp32 sums, (K,) fp32 counts."""
    onehot = jax.nn.one_hot(assignments, k, dtype=jnp.float32)
    return onehot.T @ points.astype(jnp.float32), jnp.sum(onehot, axis=0)


def flash_attention_ref(q, k, v):
    """Causal softmax attention oracle, (B, H, S, D) fp32 softmax."""
    import math
    b, h, s, d = q.shape
    sc = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                    k.astype(jnp.float32)) / math.sqrt(d)
    mask = jnp.tril(jnp.ones((s, s), bool))
    sc = jnp.where(mask, sc, -jnp.inf)
    p = jax.nn.softmax(sc, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def ssd_intra_ref(c, b, x, cum):
    """Intra-chunk SSD oracle. c,b: (G,Q,N); x: (G,Q,P); cum: (G,Q)."""
    scores = jnp.einsum("gin,gjn->gij", c.astype(jnp.float32),
                        b.astype(jnp.float32))
    diff = cum[:, :, None] - cum[:, None, :]
    q = c.shape[1]
    mask = jnp.tril(jnp.ones((q, q), bool))
    decay = jnp.where(mask[None], jnp.exp(diff.astype(jnp.float32)), 0.0)
    return jnp.einsum("gij,gjp->gip", scores * decay,
                      x.astype(jnp.float32))
