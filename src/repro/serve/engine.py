"""The serving steady loop: request micro-batching over the epoch-swapped
centroid index.

Adapted from the seed's LM serving launcher (prefill/decode steady
loop over jitted step functions) to the k-means workload: requests are
ragged (m, D) query blocks, the "step" is one batched exact assign
(:func:`repro.core.engine.make_serve_assign`), and the model state is
a :class:`~repro.serve.index.CentroidSnapshot` acquired fresh per
batch, so a centroid publish lands between batches, never inside one.

Shape discipline is what makes this fast: coalesced batches pad up to
a pow2 bucket in ``[min_bucket, max_batch]``, so the set of compiled
programs is the bucket lattice — ragged traffic never recompiles, and
an epoch swap never recompiles (centroids are runtime arguments of the
jitted assign). Pad buffers are reused per bucket (no per-batch
allocation, and no zeroing — padded rows produce labels that are
sliced away).
"""
from __future__ import annotations

import queue
import threading
import time
from concurrent.futures import Future
from typing import NamedTuple

import jax
import numpy as np

from ..core import engine as _engine
from ..obs import normalize_obs
from ..tune import DEFAULT_SERVE_CONFIG, ServeConfig, lookup_serve
from .index import CentroidIndex

_FILL_BUCKETS = (0.125, 0.25, 0.375, 0.5, 0.625, 0.75, 0.875, 1.0)


class ServeResult(NamedTuple):
    """One request's response: labels + the exact epoch that produced
    them (the swap-consistency contract: ONE epoch, never a mix)."""
    labels: np.ndarray              # (m,) int32
    epoch: int


class _Request(NamedTuple):
    points: np.ndarray              # (m, D) f32
    future: Future
    t_submit: float
    part: "_Split | None"           # set when a jumbo request was split


class _Split:
    """Aggregates the parts of a request larger than ``max_batch``.
    Parts are served in submission order by possibly different batches
    (and epochs); the user future resolves with the FIRST part's epoch
    and the concatenated labels once every part lands. The first part
    that fails fails the whole request — later parts are ignored, so
    the user future resolves exactly once either way."""

    def __init__(self, future: Future, n_parts: int):
        self.future = future
        self.labels: list = [None] * n_parts
        self.epochs: list = [None] * n_parts
        self._left = n_parts
        self._failed = False
        self._lock = threading.Lock()

    def deliver(self, i: int, labels: np.ndarray, epoch: int) -> None:
        with self._lock:
            if self._failed:
                return
            self.labels[i] = labels
            self.epochs[i] = epoch
            self._left -= 1
            done = self._left == 0
        if done:
            self.future.set_result(ServeResult(
                np.concatenate(self.labels), self.epochs[0]))

    def fail(self, exc: BaseException) -> None:
        with self._lock:
            if self._failed:
                return
            self._failed = True
        self.future.set_exception(exc)

    def on_part(self, i: int):
        """Done-callback for part ``i``'s future. Raising inside
        ``add_done_callback`` is swallowed by concurrent.futures, so
        the exception check must happen here, not via ``f.result()``."""
        def cb(f: Future) -> None:
            exc = f.exception()
            if exc is not None:
                self.fail(exc)
            else:
                self.deliver(i, *f.result())
        return cb


class ServeEngine:
    """Micro-batching front-end over a :class:`CentroidIndex`.

    ``submit`` enqueues a (m, D) query block and returns a
    ``concurrent.futures.Future`` resolving to :class:`ServeResult`;
    a background thread drains the queue, coalesces requests up to
    ``config.max_batch`` points, pads to the pow2 bucket, binds ONE
    index snapshot, runs the batched assign, and fans the label slices
    back out. ``assign`` is the synchronous convenience wrapper.

    Configuration comes from ``config=`` or the tuned ``serve|`` cache
    family (:func:`repro.tune.lookup_serve`) when ``tune != "off"``.
    Use as a context manager, or ``start()``/``stop()`` explicitly.
    """

    def __init__(self, index: CentroidIndex, *,
                 config: ServeConfig | None = None, tune: str = "on",
                 obs=None, interpret: bool | None = None):
        self._index = index
        self._cfg = config
        self._tune = tune
        self._obs = normalize_obs(obs)
        self._interpret = interpret
        self._q: queue.Queue = queue.Queue()
        self._thread: threading.Thread | None = None
        self._running = False
        self._buffers: dict = {}        # bucket -> reused (bucket, D) f32
        self._assigns: dict = {}        # (k, n_groups, donate) -> fn
        self._last_epoch = None
        self.batches = 0
        self.points = 0
        self.epoch_swaps = 0
        self._metrics = None

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> "ServeEngine":
        if self._running:
            return self
        if self._obs is not None:
            reg = self._obs.resolve_registry()
            self._metrics = {
                "depth": reg.gauge("serve_queue_depth",
                                   "requests waiting in the serve queue"),
                "fill": reg.histogram(
                    "serve_batch_fill",
                    "coalesced points / bucket capacity per batch",
                    buckets=_FILL_BUCKETS),
                "batches": reg.counter("serve_batches_total",
                                       "batches served"),
                "points": reg.counter("serve_points_total",
                                      "query points served"),
                "swaps": reg.counter(
                    "serve_epoch_swaps_total",
                    "batches that first observed a new epoch"),
                "latency": reg.histogram(
                    "serve_latency_seconds",
                    "submit-to-labels latency per request"),
            }
        self._running = True
        self._thread = threading.Thread(target=self._loop,
                                        name="serve-engine", daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        """Drain outstanding requests, then stop the loop."""
        if not self._running:
            return
        self._running = False
        self._q.put(None)               # wake the loop
        self._thread.join()
        self._thread = None

    def __enter__(self) -> "ServeEngine":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- client side -------------------------------------------------------

    def submit(self, points) -> Future:
        """Enqueue one query block; returns a Future of
        :class:`ServeResult`. Blocks >``max_batch`` points are split
        into max_batch-sized parts transparently.

        A device-resident f32 ``jax.Array`` block skips host staging
        entirely: the exact-fit batch path hands it straight to the
        jitted assign, so in-process clients that already hold device
        arrays (a streaming fitter re-labelling its shards, a VQ
        pipeline) pay no host round-trip. Host numpy blocks pay one
        staging copy."""
        if not self._running:
            raise RuntimeError("ServeEngine is not running; call "
                               "start() or use it as a context manager")
        if not (isinstance(points, jax.Array)
                and points.dtype == np.float32):
            points = np.ascontiguousarray(points, dtype=np.float32)
        if points.ndim != 2:
            raise ValueError(f"points must be (m, d), got "
                             f"{points.shape}")
        snap = self._index._snap
        if snap is not None and points.shape[1] != snap.d:
            # reject here, synchronously: a wrong-D block reaching the
            # serve thread would fail mid-batch instead
            raise ValueError(
                f"points have feature dim {points.shape[1]}, but the "
                f"index serves {snap.d}-dim centroids")
        fut: Future = Future()
        m = points.shape[0]
        now = time.perf_counter()
        cap = self._config().max_batch
        if m == 0:
            fut.set_result(ServeResult(np.zeros((0,), np.int32),
                                       snap.epoch if snap else 0))
            return fut
        if m <= cap:
            self._q.put(_Request(points, fut, now, None))
            return fut
        parts = [points[lo:lo + cap] for lo in range(0, m, cap)]
        split = _Split(fut, len(parts))
        for i, part in enumerate(parts):
            pf: Future = Future()
            pf.add_done_callback(split.on_part(i))
            self._q.put(_Request(part, pf, now, split))
        return fut

    def assign(self, points) -> ServeResult:
        """Synchronous convenience: submit + wait."""
        return self.submit(points).result()

    # -- the steady loop ---------------------------------------------------

    def _config(self) -> ServeConfig:
        if self._cfg is not None:
            return self._cfg
        if not self._index.ready:
            # the tuned lookup needs the snapshot's (k, d); do NOT
            # memoize the fallback, or a submit racing the first
            # publish pins the default config for the engine's lifetime
            return DEFAULT_SERVE_CONFIG
        cfg = None
        if self._tune != "off":
            snap = self._index._snap
            cfg = lookup_serve(k=snap.k, d=snap.d)
        self._cfg = cfg or DEFAULT_SERVE_CONFIG
        return self._cfg

    def _bucket(self, count: int) -> int:
        cfg = self._config()
        return _engine._bucket_cap(count, cfg.min_bucket, cfg.max_batch)

    def _resolve_assign(self, snap, *, donate: bool):
        key = (snap.k, snap.n_groups, donate)
        fn = self._assigns.get(key)
        if fn is None:
            cfg = self._config()
            interpret = self._interpret
            if interpret is None:
                interpret = jax.default_backend() != "tpu"
            fn = _engine.make_serve_assign(
                (snap.k, snap.n_groups), backend=cfg.backend,
                chunk=cfg.chunk, interpret=interpret, donate=donate)
            self._assigns[key] = fn
        return fn

    def _drain(self, first: _Request) -> list:
        """Coalesce up to max_batch points, optionally lingering
        ``max_wait_us`` for batch fill."""
        cfg = self._config()
        reqs = [first]
        total = first.points.shape[0]
        deadline = first.t_submit + cfg.max_wait_us * 1e-6
        while total < cfg.max_batch:
            try:
                nxt = self._q.get_nowait()
            except queue.Empty:
                wait = deadline - time.perf_counter()
                if wait <= 0:
                    break
                try:
                    nxt = self._q.get(timeout=wait)
                except queue.Empty:
                    break
            if nxt is None:             # stop sentinel: put it back
                self._q.put(None)
                break
            reqs.append(nxt)
            total += nxt.points.shape[0]
        return reqs

    def _serve_batch(self, reqs: list) -> None:
        total = sum(r.points.shape[0] for r in reqs)
        bucket = self._bucket(total)
        if len(reqs) == 1 and reqs[0].points.shape[0] == bucket:
            batch = reqs[0].points      # exact-fit fast path: zero copy
        else:
            d = reqs[0].points.shape[1]
            buf = self._buffers.get(bucket)
            if buf is None or buf.shape[1] != d:
                buf = np.empty((bucket, d), np.float32)
                self._buffers[bucket] = buf
            off = 0
            for r in reqs:
                m = r.points.shape[0]
                buf[off:off + m] = r.points
                off += m
            batch = buf                 # rows >= total are stale — fine,
        snap = self._index.acquire()    # their labels are sliced away
        # donation only for engine-staged input (numpy: jit transfers a
        # fresh device copy per call, so donating it is free). A client
        # jax.Array on the exact-fit path must NOT be donated — the
        # client keeps using its buffer (submit() advertises exactly
        # that), and donation would invalidate it in place.
        donate = (jax.default_backend() != "cpu"
                  and not isinstance(batch, jax.Array))
        fn = self._resolve_assign(snap, donate=donate)
        labels = np.asarray(fn(batch, snap.centroids, snap.c2,
                               snap.groups, snap.members, snap.gsize))
        now = time.perf_counter()
        off = 0
        for r in reqs:
            m = r.points.shape[0]
            r.future.set_result(ServeResult(labels[off:off + m],
                                            snap.epoch))
            off += m
        self.batches += 1
        self.points += total
        swapped = self._last_epoch is not None \
            and snap.epoch != self._last_epoch
        if swapped:
            self.epoch_swaps += 1
        self._last_epoch = snap.epoch
        if self._metrics is not None:
            mt = self._metrics
            mt["depth"].set(float(self._q.qsize()))
            mt["fill"].observe(total / bucket)
            mt["batches"].inc()
            mt["points"].inc(float(total))
            if swapped:
                mt["swaps"].inc()
            for r in reqs:
                mt["latency"].observe(now - r.t_submit)

    def _serve_safely(self, reqs: list) -> None:
        """One batch, fault-isolated: any error (backend failure, bad
        input that slipped past submit validation) fails THIS batch's
        futures and leaves the serve thread alive for the next batch —
        an unhandled raise here would kill the daemon thread silently
        and hang every pending and future request forever."""
        try:
            self._serve_batch(reqs)
        except BaseException as e:
            for r in reqs:
                if not r.future.done():
                    r.future.set_exception(e)

    def _loop(self) -> None:
        while True:
            try:
                first = self._q.get(timeout=0.05)
            except queue.Empty:
                if not self._running:
                    return
                continue
            if first is None:
                if self._running:       # spurious wake
                    continue
                # drain what's left, then exit
                rest = []
                while True:
                    try:
                        r = self._q.get_nowait()
                    except queue.Empty:
                        break
                    if r is not None:
                        rest.append(r)
                for r in rest:
                    if self._index.ready:
                        self._serve_safely([r])
                    else:
                        r.future.set_exception(RuntimeError(
                            "ServeEngine stopped before any centroids "
                            "were published"))
                return
            if not self._index.ready:
                # nothing published yet: requeue and wait briefly
                self._q.put(first)
                time.sleep(0.005)
                continue
            self._serve_safely(self._drain(first))
