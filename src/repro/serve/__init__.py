"""K-means as a live index: batched low-latency centroid serving.

The serving subsystem (see ``docs/serving.md``) turns fitted centroids
into an online assignment service:

* :class:`CentroidIndex` — double-buffered epoch swap: fitters
  ``publish()`` new centroids (group tables rebuilt or reused on the
  drift ledger's word), servers ``acquire()`` immutable snapshots.
  Serving never blocks on fitting, and a query batch sees exactly one
  epoch.
* :class:`ServeEngine` — request micro-batching with a steady loop:
  pow2 bucket padding (ragged traffic never recompiles), one snapshot
  per batch, the batched exact assign hot path
  (``engine.make_serve_assign``), metrics on the shared registry.
* ``StreamingKMeans.attach_index(index)`` — continuous refresh: the
  streaming fitter publishes after every committed mini-batch.

Quick start::

    from repro.serve import CentroidIndex, ServeEngine

    index = CentroidIndex(km.cluster_centers_)
    with ServeEngine(index) as eng:
        labels, epoch = eng.assign(queries)
"""
from .engine import ServeEngine, ServeResult
from .index import CentroidIndex, CentroidSnapshot

__all__ = ["CentroidIndex", "CentroidSnapshot", "ServeEngine",
           "ServeResult"]
