"""Double-buffered centroid index: publish/acquire epoch swap.

The serving consistency contract (``docs/serving.md``) in one line:
**a query batch sees exactly one epoch**. :class:`CentroidIndex` makes
that structural — every :meth:`publish` builds a fully immutable
:class:`CentroidSnapshot` (centroids, cached norms, group tables, all
device-resident) and swaps it in atomically; :meth:`acquire` hands out
the current snapshot as one reference. Serving binds ONE snapshot per
batch, so fitting and serving never block each other and no batch can
mix centroids from two epochs.

The drift ledger decides table work: group tables only steer pruning
(any valid centroid partition is exact — ``engine.serve_assign_*``
never depends on table freshness for correctness), so a publish whose
cumulative drift since the last rebuild stays under
``rebuild_threshold`` x the typical centroid norm REUSES the previous
snapshot's tables and skips the ``group_centroids`` mini-kmeans
entirely. Large drift rebuilds, restoring pruning quality.
"""
from __future__ import annotations

import dataclasses
import threading

import jax.numpy as jnp
import numpy as np

from ..core import engine as _engine
from ..core.distances import row_norms_sq
from ..obs import normalize_obs


@dataclasses.dataclass(frozen=True)
class CentroidSnapshot:
    """One immutable published epoch: centroids + everything the
    batched assign needs, so serving a batch touches no mutable
    state. ``groups``/``members``/``gsize`` are the inference-side
    group tables (possibly REUSED from an earlier epoch — exact
    either way)."""
    epoch: int
    centroids: jnp.ndarray          # (K, D) f32
    c2: jnp.ndarray                 # (K,)  f32 cached ||c||^2
    groups: jnp.ndarray             # (K,)  int32 centroid -> group
    members: jnp.ndarray            # (G, Lmax) int32, -1 padded
    gsize: jnp.ndarray              # (G,)  f32
    tables_epoch: int               # epoch whose publish BUILT the tables

    @property
    def k(self) -> int:
        return int(self.centroids.shape[0])

    @property
    def d(self) -> int:
        return int(self.centroids.shape[1])

    @property
    def n_groups(self) -> int:
        return int(self.gsize.shape[0])


class CentroidIndex:
    """Lock-free-read, double-buffered centroid store.

    Writers (the streaming fitter, or anyone holding new centroids)
    call :meth:`publish`; readers (:class:`repro.serve.ServeEngine`)
    call :meth:`acquire` and keep the returned snapshot for exactly
    one batch. The swap is a single reference assignment under a lock
    — readers never wait on table builds, which happen on the
    publisher's thread before the swap.

    ``rebuild_threshold`` gates table rebuilds on the publisher's
    cumulative drift (``cum_drift=``, the streaming fitter passes its
    float64 drift ledger): rebuild when any centroid has moved more
    than ``rebuild_threshold * sqrt(mean ||c||^2)`` since the tables
    were last built. Publishes without drift information always
    rebuild (the safe default for arbitrary centroid jumps).
    """

    def __init__(self, centroids=None, *, n_groups: int | None = None,
                 rebuild_threshold: float = 0.05, obs=None):
        self.n_groups = n_groups
        self.rebuild_threshold = float(rebuild_threshold)
        self._lock = threading.Lock()
        self._snap: CentroidSnapshot | None = None
        self._drift_at_rebuild: np.ndarray | None = None
        self._rebuild_scale = 0.0
        self.publishes = 0
        self.rebuilds = 0
        self.reuses = 0
        self._obs = normalize_obs(obs)
        if centroids is not None:
            self.publish(centroids)

    # -- writer side -------------------------------------------------------

    def _should_rebuild(self, snap, centroids, cum_drift,
                        force_rebuild) -> bool:
        if force_rebuild or snap is None or cum_drift is None:
            return True
        if centroids.shape != snap.centroids.shape:
            return True
        if self._drift_at_rebuild is None or \
                len(cum_drift) != len(self._drift_at_rebuild):
            return True
        moved = float(np.max(np.asarray(cum_drift)
                             - self._drift_at_rebuild))
        return moved > self.rebuild_threshold * self._rebuild_scale

    def publish(self, centroids, *, cum_drift=None,
                force_rebuild: bool = False) -> int:
        """Swap in a new epoch; returns its epoch number.

        ``cum_drift`` — (K,) cumulative per-centroid drift (the
        streaming fitter's ledger); enables table REUSE under the
        drift threshold. ``force_rebuild`` rebuilds unconditionally.
        Never called concurrently with itself (one fitter owns the
        index); safe against any number of concurrent readers.
        """
        centroids = jnp.asarray(centroids)
        if centroids.dtype != jnp.float32:
            centroids = centroids.astype(jnp.float32)
        c2 = row_norms_sq(centroids)
        snap = self._snap
        epoch = (snap.epoch if snap else 0) + 1
        if self._should_rebuild(snap, centroids, cum_drift, force_rebuild):
            groups, members, gsize = _engine.build_assign_tables(
                centroids, self.n_groups)
            tables_epoch = epoch
            self._drift_at_rebuild = (
                None if cum_drift is None
                else np.asarray(cum_drift, np.float64).copy())
            self._rebuild_scale = float(
                jnp.sqrt(jnp.mean(c2) + 1e-12))
            self.rebuilds += 1
        else:
            groups, members, gsize = snap.groups, snap.members, snap.gsize
            tables_epoch = snap.tables_epoch
            self.reuses += 1
        new = CentroidSnapshot(epoch=epoch, centroids=centroids, c2=c2,
                               groups=groups, members=members,
                               gsize=gsize, tables_epoch=tables_epoch)
        with self._lock:
            self._snap = new
        self.publishes += 1
        if self._obs is not None:
            reg = self._obs.resolve_registry()
            reg.counter("serve_publishes_total",
                        "centroid epochs published").inc()
            reg.counter("serve_table_rebuilds_total",
                        "publishes that rebuilt group tables").inc(
                1.0 if tables_epoch == epoch else 0.0)
            reg.gauge("serve_epoch", "current published epoch").set(
                float(epoch))
        return epoch

    # -- reader side -------------------------------------------------------

    @property
    def ready(self) -> bool:
        return self._snap is not None

    def acquire(self) -> CentroidSnapshot:
        """The current snapshot. Hold it for one batch; never cache it
        across batches (that would pin an old epoch alive)."""
        with self._lock:
            snap = self._snap
        if snap is None:
            raise RuntimeError(
                "CentroidIndex has no published centroids yet; call "
                "publish() (or attach a fitter) first")
        return snap
