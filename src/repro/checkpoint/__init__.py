"""Sharded atomic checkpointing."""
from .checkpoint import (CheckpointCorruptError, available_steps,
                         latest_step, load_checkpoint_arrays,
                         restore_checkpoint, save_checkpoint)

__all__ = [
    "save_checkpoint", "restore_checkpoint", "latest_step",
    "available_steps", "load_checkpoint_arrays", "CheckpointCorruptError",
]
