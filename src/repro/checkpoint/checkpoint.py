"""Sharded, atomic, async checkpointing with elastic restore.

Layout (no external deps — npz shards + a JSON manifest):

    <dir>/step_000123/
        manifest.json          {step, tree structure, leaf shapes/dtypes}
        shard_<host>.npz       one file per host: every leaf's
                               host-local addressable data, concatenated
                               by flat leaf index
    <dir>/LATEST               atomic pointer (text: "step_000123")

Properties needed at 1000+-node scale, scaled down honestly here:
  * per-host shard files (no single-writer bottleneck),
  * write-to-temp + atomic rename (a crashed save never corrupts LATEST),
  * async save thread (training continues during serialization),
  * ELASTIC restore: the manifest stores global shapes, restore
    device_puts into ANY new mesh/sharding (mesh size can change
    between runs — the npz holds full global arrays per leaf on a
    single-process runtime; multi-host would store per-host slices +
    offsets, same manifest format).
"""
from __future__ import annotations

import json
import shutil
import threading
import time
from pathlib import Path

import jax
import numpy as np


def _flat_with_paths(tree):
    flat, treedef = jax.tree.flatten(tree)
    return flat, treedef


def save_checkpoint(ckpt_dir, step: int, state, *, async_: bool = False):
    """Serialise ``state`` (any pytree of jax/np arrays) for ``step``."""
    ckpt_dir = Path(ckpt_dir)

    # Snapshot to host memory synchronously (cheap), write async.
    flat, _ = _flat_with_paths(state)
    host_leaves = [np.asarray(x) for x in flat]

    def _write():
        step_dir = ckpt_dir / f"step_{step:06d}"
        tmp_dir = ckpt_dir / f".tmp_step_{step:06d}_{time.time_ns()}"
        tmp_dir.mkdir(parents=True, exist_ok=True)
        manifest = {
            "step": step,
            "leaves": [{"shape": list(x.shape), "dtype": str(x.dtype)}
                       for x in host_leaves],
        }
        (tmp_dir / "manifest.json").write_text(json.dumps(manifest))
        np.savez(tmp_dir / "shard_0.npz",
                 **{f"leaf_{i}": x for i, x in enumerate(host_leaves)})
        if step_dir.exists():
            shutil.rmtree(step_dir)
        tmp_dir.rename(step_dir)                     # atomic publish
        latest_tmp = ckpt_dir / ".LATEST.tmp"
        latest_tmp.write_text(step_dir.name)
        latest_tmp.rename(ckpt_dir / "LATEST")       # atomic pointer

    if async_:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def latest_step(ckpt_dir) -> int | None:
    ptr = Path(ckpt_dir) / "LATEST"
    if not ptr.exists():
        return None
    return int(ptr.read_text().strip().split("_")[-1])


def restore_checkpoint(ckpt_dir, like, *, step: int | None = None,
                       shardings=None):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs). ``shardings`` may target a DIFFERENT mesh than
    the one that saved — elastic restart."""
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    step_dir = ckpt_dir / f"step_{step:06d}"
    data = np.load(step_dir / "shard_0.npz")
    flat_like, treedef = jax.tree.flatten(like)
    leaves = [data[f"leaf_{i}"] for i in range(len(flat_like))]
    for got, want in zip(leaves, flat_like):
        if tuple(got.shape) != tuple(want.shape):
            raise ValueError(
                f"checkpoint leaf shape {got.shape} != expected {want.shape}")
    if shardings is not None:
        flat_sh = treedef.flatten_up_to(shardings)
        leaves = [jax.device_put(x, s) for x, s in zip(leaves, flat_sh)]
    else:
        leaves = [jax.device_put(np.asarray(x)) for x in leaves]
    return treedef.unflatten(leaves), step
