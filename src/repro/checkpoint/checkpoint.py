"""Sharded, atomic, async checkpointing with elastic restore.

Layout (no external deps — npz shards + a JSON manifest):

    <dir>/step_000123/
        manifest.json          {step, leaf shapes/dtypes, optional meta}
        shard_<host>.npz       one file per host: every leaf's
                               host-local addressable data, concatenated
                               by flat leaf index
    <dir>/LATEST               atomic pointer (text: "step_000123")

Properties needed at 1000+-node scale, scaled down honestly here:
  * per-host shard files (no single-writer bottleneck),
  * write-to-temp + atomic rename (a crashed save never corrupts LATEST),
  * async save thread (training continues during serialization),
  * ELASTIC restore: the manifest stores global shapes, restore
    device_puts into ANY new mesh/sharding (mesh size can change
    between runs — the npz holds full global arrays per leaf on a
    single-process runtime; multi-host would store per-host slices +
    offsets, same manifest format),
  * VALIDATED restore with fallback: a torn/corrupt step (missing or
    unreadable manifest / shard file, leaf count or shape drift) is
    rejected — ``fallback=True`` walks back to the newest COMPLETE
    save instead of failing the run, even when LATEST itself points at
    the corrupt step.

Two access levels:

* :func:`save_checkpoint` / :func:`restore_checkpoint` — the pytree
  API (arrays in, arrays out, optional sharding re-targeting).
* :func:`load_checkpoint_arrays` — raw host numpy leaves + the
  manifest, NO device placement. Callers whose state is not a plain
  device pytree (e.g. ``repro.streaming``'s stream state: a float64
  drift ledger, variable-structure bound cache, host scalars in
  ``meta``) restore through this so nothing is silently cast by
  ``jax.device_put`` (x64 is disabled on device; the ledger must stay
  float64 on the host).
"""
from __future__ import annotations

import json
import shutil
import threading
import time
from pathlib import Path

import jax
import numpy as np


class CheckpointCorruptError(RuntimeError):
    """A step directory exists but cannot be restored (partial write,
    truncated shard, manifest/leaf mismatch)."""


def _flat_with_paths(tree):
    flat, treedef = jax.tree.flatten(tree)
    return flat, treedef


def save_checkpoint(ckpt_dir, step: int, state, *, async_: bool = False,
                    meta: dict | None = None):
    """Serialise ``state`` (any pytree of jax/np arrays) for ``step``.

    ``meta``: optional JSON-serialisable blob stored in the manifest —
    the side-channel for host scalars / structure descriptions that are
    not array leaves (``load_checkpoint_arrays`` hands it back). The
    host snapshot (``np.asarray`` per leaf) happens synchronously;
    callers passing host arrays they mutate IN PLACE must snapshot
    copies themselves before an ``async_=True`` save."""
    ckpt_dir = Path(ckpt_dir)

    # Snapshot to host memory synchronously (cheap), write async.
    flat, _ = _flat_with_paths(state)
    host_leaves = [np.asarray(x) for x in flat]

    def _write():
        step_dir = ckpt_dir / f"step_{step:06d}"
        tmp_dir = ckpt_dir / f".tmp_step_{step:06d}_{time.time_ns()}"
        tmp_dir.mkdir(parents=True, exist_ok=True)
        manifest = {
            "step": step,
            "leaves": [{"shape": list(x.shape), "dtype": str(x.dtype)}
                       for x in host_leaves],
        }
        if meta is not None:
            manifest["meta"] = meta
        (tmp_dir / "manifest.json").write_text(json.dumps(manifest))
        np.savez(tmp_dir / "shard_0.npz",
                 **{f"leaf_{i}": x for i, x in enumerate(host_leaves)})
        if step_dir.exists():
            shutil.rmtree(step_dir)
        tmp_dir.rename(step_dir)                     # atomic publish
        latest_tmp = ckpt_dir / ".LATEST.tmp"
        latest_tmp.write_text(step_dir.name)
        latest_tmp.rename(ckpt_dir / "LATEST")       # atomic pointer

    if async_:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def latest_step(ckpt_dir) -> int | None:
    ptr = Path(ckpt_dir) / "LATEST"
    if not ptr.exists():
        return None
    return int(ptr.read_text().strip().split("_")[-1])


def available_steps(ckpt_dir) -> list[int]:
    """All published step numbers under ``ckpt_dir``, ascending.
    Published = the atomic rename happened (``.tmp_*`` dirs from
    crashed saves are invisible); a published dir may still be corrupt
    on disk-level damage — :func:`load_checkpoint_arrays` validates."""
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.is_dir():
        return []
    steps = []
    for p in ckpt_dir.iterdir():
        if p.is_dir() and p.name.startswith("step_"):
            try:
                steps.append(int(p.name.split("_")[-1]))
            except ValueError:
                continue
    return sorted(steps)


def _load_step(ckpt_dir: Path, step: int):
    """Read + validate one step. Raises CheckpointCorruptError on any
    torn/partial/inconsistent state."""
    step_dir = ckpt_dir / f"step_{step:06d}"
    if not step_dir.is_dir():
        raise CheckpointCorruptError(f"{step_dir} does not exist")
    try:
        manifest = json.loads((step_dir / "manifest.json").read_text())
    except (OSError, ValueError) as e:
        raise CheckpointCorruptError(
            f"unreadable manifest in {step_dir}: {e}") from e
    try:
        data = np.load(step_dir / "shard_0.npz")
        leaves = [data[f"leaf_{i}"]
                  for i in range(len(manifest["leaves"]))]
    except (OSError, ValueError, KeyError) as e:
        raise CheckpointCorruptError(
            f"unreadable/partial shard in {step_dir}: {e}") from e
    for got, want in zip(leaves, manifest["leaves"]):
        if list(got.shape) != list(want["shape"]):
            raise CheckpointCorruptError(
                f"leaf shape {got.shape} != manifest {want['shape']} "
                f"in {step_dir}")
    return manifest, leaves


def load_checkpoint_arrays(ckpt_dir, *, step: int | None = None,
                           fallback: bool = False):
    """Load ``(step, manifest, leaves)`` — host numpy, no device_put.

    ``step=None`` starts from the LATEST pointer (or the newest
    published step when the pointer is missing/stale). ``fallback=True``
    walks back through older complete saves when the requested/latest
    one is corrupt or partial — the restart story for a host that died
    MID-save (the atomic rename makes that window tiny but a torn disk
    is still representable). Raises :class:`FileNotFoundError` when no
    checkpoint exists at all, :class:`CheckpointCorruptError` when the
    requested step is damaged and fallback is off (or every candidate
    is damaged)."""
    ckpt_dir = Path(ckpt_dir)
    if step is not None:
        candidates = [step]
        if fallback:
            candidates += [s for s in reversed(available_steps(ckpt_dir))
                           if s < step]
    else:
        steps = available_steps(ckpt_dir)
        if not steps:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
        newest = latest_step(ckpt_dir)
        if newest is None or newest not in steps:
            newest = steps[-1]
        candidates = [newest] if not fallback else \
            [newest] + [s for s in reversed(steps) if s != newest]
    last_err: Exception | None = None
    for s in candidates:
        try:
            manifest, leaves = _load_step(ckpt_dir, s)
            return s, manifest, leaves
        except CheckpointCorruptError as e:
            last_err = e
            continue
    raise last_err if last_err is not None else \
        FileNotFoundError(f"no checkpoint under {ckpt_dir}")


def restore_checkpoint(ckpt_dir, like, *, step: int | None = None,
                       shardings=None, fallback: bool = False):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs). ``shardings`` may target a DIFFERENT mesh than
    the one that saved — elastic restart. ``fallback=True`` drops back
    to the newest complete save when the latest is corrupt/partial."""
    step, _, leaves = load_checkpoint_arrays(ckpt_dir, step=step,
                                             fallback=fallback)
    flat_like, treedef = jax.tree.flatten(like)
    if len(leaves) != len(flat_like):
        raise ValueError(
            f"checkpoint has {len(leaves)} leaves, expected "
            f"{len(flat_like)}")
    for got, want in zip(leaves, flat_like):
        if tuple(got.shape) != tuple(want.shape):
            raise ValueError(
                f"checkpoint leaf shape {got.shape} != expected {want.shape}")
    if shardings is not None:
        flat_sh = treedef.flatten_up_to(shardings)
        leaves = [jax.device_put(x, s) for x, s in zip(leaves, flat_sh)]
    else:
        leaves = [jax.device_put(np.asarray(x)) for x in leaves]
    return treedef.unflatten(leaves), step
