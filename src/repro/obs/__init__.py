"""repro.obs — observability for the KPynq engine family.

Three layers (see ``docs/observability.md``):

* :mod:`repro.obs.ring` — the device-resident per-iteration telemetry
  ring: layout constants, shard-ring reduction, summaries, the
  live-drain listener registry. The device side lives in
  ``repro.core.engine`` (``EngineCarry.ring``); this module owns the
  host-side semantics.
* :mod:`repro.obs.trace` — phase tracing: ``jax.named_scope`` device
  phases (annotated in the engine), :func:`profile` for Perfetto
  traces, :func:`span` for host wall-clock spans.
* :mod:`repro.obs.metrics` — the metrics registry
  (counter/gauge/histogram + JSONL event log) with Prometheus-text and
  JSONL exporters, published by all three fit drivers.

This package deliberately imports nothing from ``repro.core`` so the
engine can import it without cycles.
"""
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      ObsConfig, default_registry, normalize_obs,
                      provenance, reset_default_registry)
from .ring import (N_COUNTERS, RING_COLUMNS, add_ring_listener,
                   caps_from_ring, format_ring_table, reduce_shard_rings,
                   remove_ring_listener, shard_skew, summarize_ring)
from .trace import profile, span

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "ObsConfig",
    "default_registry", "normalize_obs", "provenance",
    "reset_default_registry",
    "N_COUNTERS", "RING_COLUMNS", "add_ring_listener", "caps_from_ring",
    "format_ring_table", "reduce_shard_rings", "remove_ring_listener",
    "shard_skew", "summarize_ring",
    "profile", "span",
]
