"""Device-resident per-iteration telemetry ring: layout + host-side
consumers.

The engine's whole fit runs under ``lax.while_loop`` with zero host
round-trips per iteration — which makes the per-iteration dynamics
(candidate survival, group pruning, bucket transitions, drift)
invisible exactly when you need them: debugging a filter-hostile
dataset or a mistuned capacity ladder. The ring makes them visible
WITHOUT breaking the zero-sync contract: a fixed
``(max_iters + 1, N_COUNTERS)`` fp32 buffer rides in the loop carry
(``EngineCarry.ring``), each loop body writes one row at its iteration
index, the epilogue writes the final row, and the whole buffer is
drained ONCE at fit exit (``EngineStats.ring``). ``host_syncs`` is
unchanged by construction — the drain rides the exit fetch the driver
already does.

Row layout (``RING_COLUMNS``, all fp32):

======  =================  ==============================================
index   column             semantics (per completed iteration)
======  =================  ==============================================
0       ``n_cand``         pending candidate count after this
                           iteration's move (points the NEXT pass must
                           score) — shard-local under ``shard_map``
1       ``gmax``           surviving-group high-water observed by the
                           candidate pass that ran this iteration (0 for
                           the oracle/pallas passes, which don't compact)
2       ``shift``          max centroid drift of this iteration's move
3       ``evals``          distance evaluations ADDED this iteration
                           (candidate-pass pairs + own-distance
                           refreshes) — the increments the fit's
                           ``EvalCount`` accumulates, so
                           ``init_evals + sum(evals column) ==
                           result.distance_evals`` exactly (the final
                           row is the epilogue's pending pass)
4       ``cap_n``          active point-capacity bucket (N for the
                           non-compacting backends)
5       ``cap_g``          active group-capacity bucket
6       ``inertia_proxy``  running sum of squared upper bounds — an
                           upper-bound estimate of inertia (weighted
                           when the fit is); the final (epilogue) row
                           holds the EXACT inertia
7       ``tightened``      own-distance refreshes spent this iteration
======  =================  ==============================================

Rows are shard-local under the distributed driver; stack them along a
leading shard axis and :func:`reduce_shard_rings` produces the global
view (sums for additive columns, maxima for high-waters/capacities).
"""
from __future__ import annotations

import numpy as np

RING_COLUMNS = ("n_cand", "gmax", "shift", "evals", "cap_n", "cap_g",
                "inertia_proxy", "tightened")
N_COUNTERS = len(RING_COLUMNS)

# column indices, importable by name
COL_N_CAND = 0
COL_GMAX = 1
COL_SHIFT = 2
COL_EVALS = 3
COL_CAP_N = 4
COL_CAP_G = 5
COL_INERTIA = 6
COL_TIGHTENED = 7

# reduction rule per column when joining per-shard rings: additive
# counters sum, high-waters / capacities / drift take the max (drift is
# replicated across shards — max == the common value)
_REDUCE_SUM = (COL_N_CAND, COL_EVALS, COL_INERTIA, COL_TIGHTENED)
_REDUCE_MAX = (COL_GMAX, COL_SHIFT, COL_CAP_N, COL_CAP_G)


def reduce_shard_rings(shard_rings) -> np.ndarray:
    """Join per-shard rings ``(S, R, C)`` into the global ``(R, C)``
    view: candidate counts / evals / inertia proxies sum across shards,
    group high-waters and capacity levels take the worst shard, and the
    (replicated) drift column is unchanged by its max."""
    r = np.asarray(shard_rings, np.float64)
    if r.ndim != 3 or r.shape[-1] != N_COUNTERS:
        raise ValueError(f"expected (S, R, {N_COUNTERS}) shard rings, "
                         f"got shape {r.shape}")
    out = np.zeros(r.shape[1:], np.float64)
    out[:, list(_REDUCE_SUM)] = r[:, :, list(_REDUCE_SUM)].sum(axis=0)
    out[:, list(_REDUCE_MAX)] = r[:, :, list(_REDUCE_MAX)].max(axis=0)
    return out.astype(np.float32)


def shard_skew(shard_rings) -> np.ndarray:
    """Per-iteration work skew across shards: ``max / mean`` of the
    per-shard distance-eval increments (1.0 = perfectly balanced; the
    straggler signal under lockstep SPMD, where all shards WAIT for the
    worst one). Returns ``(R,)``; iterations with zero work report 1.0.
    """
    r = np.asarray(shard_rings, np.float64)[:, :, COL_EVALS]  # (S, R)
    mean = r.mean(axis=0)
    mx = r.max(axis=0)
    return np.where(mean > 0, mx / np.maximum(mean, 1e-12),
                    1.0).astype(np.float32)


def summarize_ring(ring, n_points: int, *, init_evals: float = 0.0) -> dict:
    """Headline telemetry of one fit's drained ring — the per-dataset
    summary the benchmark record carries. ``ring`` is the trimmed
    ``(n_iters + 1, C)`` buffer (final row = epilogue); ``n_points``
    normalises the candidate fraction."""
    ring = np.asarray(ring, np.float64)
    if ring.size == 0:
        return {"iters": 0, "mean_candidate_fraction": 0.0,
                "total_evals": float(init_evals), "mean_gmax": 0.0,
                "final_shift": 0.0}
    iters = max(ring.shape[0] - 1, 0)       # last row is the epilogue
    body = ring[:iters] if iters else ring[:0]
    n = max(float(n_points), 1.0)
    return {
        "iters": int(iters),
        "mean_candidate_fraction":
            float(body[:, COL_N_CAND].mean() / n) if iters else 0.0,
        "total_evals": float(ring[:, COL_EVALS].sum() + init_evals),
        "mean_gmax": float(body[:, COL_GMAX].mean()) if iters else 0.0,
        "final_shift": float(body[-1, COL_SHIFT]) if iters else 0.0,
    }


def caps_from_ring(ring) -> list:
    """The capacity-ladder trajectory as the host bucket picker would
    report it: consecutive distinct ``(cap_n, cap_g)`` pairs over the
    per-iteration rows (epilogue row excluded)."""
    ring = np.asarray(ring)
    caps = []
    for row in ring[:max(ring.shape[0] - 1, 0)]:
        pair = (int(row[COL_CAP_N]), int(row[COL_CAP_G]))
        if not caps or caps[-1] != pair:
            caps.append(pair)
    return caps


def format_ring_table(ring, n_points: int, *, max_rows: int = 20) -> str:
    """Human-readable per-iteration filter-efficiency table (the
    example prints this). Long fits are elided in the middle."""
    ring = np.asarray(ring, np.float64)
    rows = list(range(ring.shape[0]))
    lines = [f"{'iter':>5} {'n_cand':>9} {'cand%':>7} {'gmax':>5} "
             f"{'evals':>12} {'cap_n':>7} {'cap_g':>6} {'shift':>10}"]
    elide = len(rows) > max_rows
    if elide:
        head = rows[:max_rows // 2]
        tail = rows[-(max_rows - len(head)):]
        rows = head + [None] + tail
    n = max(float(n_points), 1.0)
    last = ring.shape[0] - 1
    for i in rows:
        if i is None:
            lines.append(f"{'...':>5}")
            continue
        r = ring[i]
        tag = "fin" if i == last else f"{i + 1}"
        lines.append(
            f"{tag:>5} {int(r[COL_N_CAND]):>9} "
            f"{100.0 * r[COL_N_CAND] / n:>6.1f}% {int(r[COL_GMAX]):>5} "
            f"{r[COL_EVALS]:>12.3g} {int(r[COL_CAP_N]):>7} "
            f"{int(r[COL_CAP_G]):>6} {r[COL_SHIFT]:>10.3g}")
    return "\n".join(lines)


# --------------------------------------------------------------------------
# live drain: io_callback listeners (ObsConfig.live_drain)
# --------------------------------------------------------------------------

_ring_listeners: list = []


def add_ring_listener(cb) -> None:
    """Register ``cb(iteration: int, row: np.ndarray)`` to receive each
    ring row as the device writes it (fits running with
    ``ObsConfig(live_drain=True)``). Rows may arrive slightly out of
    order — the iteration index is authoritative."""
    _ring_listeners.append(cb)


def remove_ring_listener(cb) -> None:
    try:
        _ring_listeners.remove(cb)
    except ValueError:
        pass


def emit_ring_row(iteration, row) -> None:
    """The io_callback target (host side). Listener exceptions are
    swallowed: a broken consumer must never kill a device loop."""
    it = int(np.asarray(iteration))
    row = np.asarray(row)
    for cb in list(_ring_listeners):
        try:
            cb(it, row)
        except Exception:
            pass
