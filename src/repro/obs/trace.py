"""Phase tracing: wall-clock spans + Perfetto profiles.

Two granularities:

* **Device phases** — the engine's loop body is annotated with
  ``jax.named_scope`` spans (``kpynq/candidate_pass``,
  ``kpynq/move_and_bounds``, ``kpynq/refresh``, ``kpynq/reduce``), so
  any profiler view of the compiled program attributes time to engine
  phases instead of a wall of fused HLO. :func:`profile` wraps a
  callable in ``jax.profiler.trace`` and returns the directory holding
  the Perfetto trace (open at https://ui.perfetto.dev, or feed to
  TensorBoard's profile plugin).
* **Host spans** — :func:`span` is a context manager timing a host
  region into a registry histogram + event (used by ``tune.autotune``
  around each measured candidate and by the benchmark harness around
  each suite section), so "where did the wall-clock go" is answerable
  from the same export as everything else.
"""
from __future__ import annotations

import contextlib
import os
import tempfile
import time

from .metrics import MetricsRegistry, default_registry

# span-duration histogram buckets: micro-benchmarks to multi-minute fits
SPAN_BUCKETS = (0.001, 0.005, 0.01, 0.05, 0.1, 0.5, 1.0, 5.0, 10.0,
                30.0, 60.0, 300.0)


@contextlib.contextmanager
def span(name: str, registry: MetricsRegistry | None = None, **fields):
    """Time a host-side region.

    Records the duration into the ``span_seconds`` histogram (labelled
    by span name) and appends a ``span`` event (with any extra
    ``fields``) to the registry's event log. Yields a dict the caller
    may add result fields to; they land in the same event.

        with obs.span("tune.measure", backend="compact") as s:
            t = measure(cfg)
            s["seconds_measured"] = t
    """
    reg = registry or default_registry()
    extra: dict = {}
    t0 = time.perf_counter()
    try:
        yield extra
    finally:
        dt = time.perf_counter() - t0
        reg.histogram("span_seconds", "host span durations",
                      labels={"span": name},
                      buckets=SPAN_BUCKETS).observe(dt)
        # span's own keys win over caller fields (never a TypeError)
        merged = {**fields, **extra, "name": name, "seconds": dt}
        reg.log_event("span", **merged)


def profile(fn, *args, trace_dir: str | None = None,
            registry: MetricsRegistry | None = None, **kwargs):
    """Run ``fn(*args, **kwargs)`` under ``jax.profiler.trace`` and
    block on its output, so the trace covers the real device work.

    Returns ``(result, trace_dir)``; the directory contains a
    Perfetto-compatible trace (``plugins/profile/<run>/*.trace.json.gz``)
    whose device timeline carries the engine's ``kpynq/*`` named-scope
    phase annotations. ``trace_dir=None`` creates one under the system
    temp dir. Also logged as a ``profile`` event in the registry so the
    export names the artifact path.
    """
    import jax

    if trace_dir is None:
        trace_dir = tempfile.mkdtemp(prefix="kpynq_trace_")
    os.makedirs(trace_dir, exist_ok=True)
    t0 = time.perf_counter()
    with jax.profiler.trace(str(trace_dir)):
        out = fn(*args, **kwargs)
        jax.block_until_ready(jax.tree.leaves(out))
    dt = time.perf_counter() - t0
    (registry or default_registry()).log_event(
        "profile", trace_dir=str(trace_dir), seconds=dt,
        fn=getattr(fn, "__name__", repr(fn)))
    return out, str(trace_dir)
