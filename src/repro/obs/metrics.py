"""Metrics registry + exporters: counters, gauges, histograms, events.

The instrumentation substrate shared by all three fit drivers (batch /
streaming / distributed), the autotuner, and the benchmark harness.
Design goals, in order:

1. **Zero cost when unused.** Nothing here touches jax; a registry is
   plain host python. The device-side telemetry (the per-iteration
   ring, ``repro.obs.ring``) is drained once at fit exit and only then
   published here — the zero-host-sync contract of the engine loop is
   never at stake.
2. **Two export formats.** ``to_prometheus()`` emits the Prometheus
   text exposition format (scrape-able as-is); ``export_jsonl()``
   writes the event log one JSON object per line (the CI perf lane
   uploads it as a workflow artifact, so every benchmark run leaves an
   attributable trail).
3. **One registry, many publishers.** ``engine.fit(obs=...)``,
   ``StreamingKMeans(obs=...)``, ``distributed_yinyang(obs=...)`` and
   the ``--check`` gate reporting all write into the same structure,
   so a single export shows the whole run.
"""
from __future__ import annotations

import dataclasses
import json
import time


def _sanitize(name: str) -> str:
    """Prometheus metric names: [a-zA-Z_:][a-zA-Z0-9_:]*."""
    out = []
    for i, ch in enumerate(name):
        if ch.isalnum() or ch in "_:":
            out.append(ch)
        else:
            out.append("_")
    s = "".join(out)
    if s and s[0].isdigit():
        s = "_" + s
    return s


def _fmt_labels(labels: dict | None) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{_sanitize(k)}="{v}"'
                     for k, v in sorted(labels.items()))
    return "{" + inner + "}"


class Counter:
    """Monotone counter (``inc`` only)."""

    kind = "counter"

    def __init__(self, name: str, help: str = "",
                 labels: dict | None = None):
        self.name = name
        self.help = help
        self.labels = dict(labels or {})
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        if v < 0:
            raise ValueError(f"counter {self.name!r} cannot decrease")
        self.value += float(v)

    def _sample_lines(self):
        return [f"{_sanitize(self.name)}{_fmt_labels(self.labels)} "
                f"{self.value:g}"]


class Gauge:
    """Point-in-time value (``set``; ``inc`` for convenience)."""

    kind = "gauge"

    def __init__(self, name: str, help: str = "",
                 labels: dict | None = None):
        self.name = name
        self.help = help
        self.labels = dict(labels or {})
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, v: float = 1.0) -> None:
        self.value += float(v)

    def _sample_lines(self):
        return [f"{_sanitize(self.name)}{_fmt_labels(self.labels)} "
                f"{self.value:g}"]


class Histogram:
    """Cumulative-bucket histogram (Prometheus semantics: each bucket
    counts observations <= its upper bound; ``+Inf`` is the total)."""

    kind = "histogram"
    DEFAULT_BUCKETS = (0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05,
                       0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0)

    def __init__(self, name: str, help: str = "",
                 labels: dict | None = None, buckets=None):
        self.name = name
        self.help = help
        self.labels = dict(labels or {})
        self.buckets = tuple(sorted(buckets or self.DEFAULT_BUCKETS))
        self.bucket_counts = [0] * len(self.buckets)
        self.count = 0
        self.sum = 0.0

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.sum += v
        for i, ub in enumerate(self.buckets):
            if v <= ub:
                self.bucket_counts[i] += 1

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def _sample_lines(self):
        base = _sanitize(self.name)
        lines = []
        for ub, c in zip(self.buckets, self.bucket_counts):
            lbl = _fmt_labels({**self.labels, "le": f"{ub:g}"})
            lines.append(f"{base}_bucket{lbl} {c}")
        lbl = _fmt_labels({**self.labels, "le": "+Inf"})
        lines.append(f"{base}_bucket{lbl} {self.count}")
        lines.append(f"{base}_sum{_fmt_labels(self.labels)} {self.sum:g}")
        lines.append(f"{base}_count{_fmt_labels(self.labels)} "
                     f"{self.count}")
        return lines


class MetricsRegistry:
    """Named metrics + a JSONL event log.

    ``counter``/``gauge``/``histogram`` are get-or-create (re-requesting
    the same name returns the same instance; a kind mismatch raises —
    the usual registry contract). ``labels`` distinguish instances of
    one name, so per-dataset / per-shard series coexist.
    """

    def __init__(self):
        self._metrics: dict = {}
        self.events: list[dict] = []

    # -- get-or-create -----------------------------------------------------

    def _get(self, cls, name, help, labels, **kw):
        key = (name, tuple(sorted((labels or {}).items())))
        m = self._metrics.get(key)
        if m is None:
            m = cls(name, help, labels, **kw)
            self._metrics[key] = m
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{m.kind}, requested {cls.kind}")
        return m

    def counter(self, name: str, help: str = "",
                labels: dict | None = None) -> Counter:
        return self._get(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "",
              labels: dict | None = None) -> Gauge:
        return self._get(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "",
                  labels: dict | None = None, buckets=None) -> Histogram:
        return self._get(Histogram, name, help, labels, buckets=buckets)

    def metrics(self) -> list:
        return list(self._metrics.values())

    # -- event log ---------------------------------------------------------

    def log_event(self, event: str, **fields) -> dict:
        evt = {"event": event, "ts": time.time(), **fields}
        self.events.append(evt)
        return evt

    # -- exporters ---------------------------------------------------------

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (one HELP/TYPE header per
        metric name, then its samples)."""
        lines = []
        seen_headers = set()
        for m in self._metrics.values():
            base = _sanitize(m.name)
            if base not in seen_headers:
                seen_headers.add(base)
                if m.help:
                    lines.append(f"# HELP {base} {m.help}")
                lines.append(f"# TYPE {base} {m.kind}")
            lines.extend(m._sample_lines())
        return "\n".join(lines) + ("\n" if lines else "")

    def export_prometheus(self, path) -> str:
        text = self.to_prometheus()
        with open(path, "w") as fh:
            fh.write(text)
        return str(path)

    def export_jsonl(self, path) -> str:
        """Event log, one JSON object per line (append-safe format;
        the file is rewritten whole each call)."""
        with open(path, "w") as fh:
            for evt in self.events:
                fh.write(json.dumps(evt, default=_json_default) + "\n")
        return str(path)

    def to_dict(self) -> dict:
        out = {}
        for m in self._metrics.values():
            key = m.name if not m.labels else \
                m.name + _fmt_labels(m.labels)
            if isinstance(m, Histogram):
                out[key] = {"count": m.count, "sum": m.sum,
                            "mean": m.mean}
            else:
                out[key] = m.value
        return out


def _json_default(o):
    try:
        import numpy as np
        if isinstance(o, np.ndarray):
            return o.tolist()
        if isinstance(o, np.generic):
            return o.item()
    except ImportError:
        pass
    return str(o)


_default_registry: MetricsRegistry | None = None


def default_registry() -> MetricsRegistry:
    """The process-global registry (spans and drivers without an
    explicit ``obs=`` land here)."""
    global _default_registry
    if _default_registry is None:
        _default_registry = MetricsRegistry()
    return _default_registry


def reset_default_registry() -> MetricsRegistry:
    """Swap in a fresh global registry (tests; benchmark isolation)."""
    global _default_registry
    _default_registry = MetricsRegistry()
    return _default_registry


# --------------------------------------------------------------------------
# observability configuration (what drivers accept as ``obs=``)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class ObsConfig:
    """Per-call observability switches.

    ring : record the device-resident per-iteration telemetry ring
        (``repro.obs.ring``; drained once at fit exit).
    live_drain : additionally emit each ring row AS IT IS WRITTEN via
        ``jax.experimental.io_callback`` to the listeners registered
        with :func:`repro.obs.ring.add_ring_listener` — for watching a
        long device-resident fit converge live. Costs one host
        callback per iteration (the zero-host-sync contract is about
        blocking round-trips; the callback is one-way) — leave it off
        for benchmarking.
    registry : where drivers publish their exit metrics/events
        (``None`` = the process-global :func:`default_registry`).
    """
    ring: bool = True
    live_drain: bool = False
    registry: MetricsRegistry | None = None

    def resolve_registry(self) -> MetricsRegistry:
        return self.registry or default_registry()


def normalize_obs(obs) -> ObsConfig | None:
    """Coerce a driver's ``obs=`` argument: ``None``/``False`` =
    disabled, ``True`` = defaults, a :class:`MetricsRegistry` =
    defaults publishing there, an :class:`ObsConfig` = itself."""
    if obs is None or obs is False:
        return None
    if obs is True:
        return ObsConfig()
    if isinstance(obs, MetricsRegistry):
        return ObsConfig(registry=obs)
    if isinstance(obs, ObsConfig):
        return obs
    raise TypeError(f"obs must be None, bool, MetricsRegistry or "
                    f"ObsConfig, got {type(obs).__name__}")


# --------------------------------------------------------------------------
# provenance (stamped into BENCH_kmeans.json by the benchmark harness)
# --------------------------------------------------------------------------

def provenance() -> dict:
    """Attribution block for benchmark records: git sha, jax version,
    platform, device count, timestamp. Every field degrades gracefully
    (no git / no jax initialised -> placeholders), so stamping can
    never fail a benchmark run."""
    rec = {"timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
           "git_sha": "unknown", "jax_version": "unknown",
           "platform": "unknown", "device_count": 0}
    try:
        import subprocess
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=10)
        if sha.returncode == 0:
            rec["git_sha"] = sha.stdout.strip()
        dirty = subprocess.run(
            ["git", "status", "--porcelain"], capture_output=True,
            text=True, timeout=10)
        if dirty.returncode == 0:
            rec["git_dirty"] = bool(dirty.stdout.strip())
    except Exception:
        pass
    try:
        import jax
        rec["jax_version"] = jax.__version__
        rec["platform"] = jax.default_backend()
        rec["device_count"] = jax.device_count()
    except Exception:
        pass
    return rec
