"""Batched serving launcher: prefill + decode loop with a KV/SSM cache.

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-780m \
      --reduced --batch 4 --prompt-len 32 --gen-len 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..models import init_cache, init_params
from ..train.steps import make_prefill_step, make_serve_step
from .mesh import make_host_mesh, make_production_mesh
from .sharding import cache_pspecs, named, param_pspecs


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--greedy", action="store_true", default=True)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = (make_production_mesh() if args.production_mesh
            else make_host_mesh())
    max_len = args.prompt_len + args.gen_len

    with mesh:
        params = init_params(jax.random.PRNGKey(args.seed), cfg)
        params = jax.device_put(params, named(mesh, param_pspecs(cfg)))
        serve = jax.jit(make_serve_step(cfg))
        prefill = jax.jit(make_prefill_step(cfg))

        rng = np.random.default_rng(args.seed)
        prompts = rng.integers(0, cfg.vocab,
                               (args.batch, args.prompt_len), dtype=np.int32)

        # prefill: one parallel pass over the prompt
        t0 = time.time()
        batch = {"tokens": jnp.asarray(prompts)}
        if cfg.n_vision_tokens:
            batch["vision_embeds"] = jnp.asarray(rng.standard_normal(
                (args.batch, cfg.n_vision_tokens, cfg.d_model),
                dtype=np.float32), dtype=cfg.compute_dtype)
        logits, cache = prefill(params, batch)
        # right-pad the prefill cache out to max_len for the decode loop
        def pad_to_max(leaf):
            if leaf.ndim >= 3 and leaf.shape[2] == args.prompt_len:
                pad = [(0, 0)] * leaf.ndim
                pad[2] = (0, max_len - args.prompt_len)
                return jnp.pad(leaf, pad)
            return leaf
        cache = jax.tree.map(pad_to_max, cache)
        jax.block_until_ready(logits)
        t_prefill = time.time() - t0

        # decode loop
        tok = jnp.argmax(logits[:, -1, :cfg.vocab], axis=-1)[:, None] \
            .astype(jnp.int32)
        generated = [tok]
        t0 = time.time()
        for i in range(args.gen_len - 1):
            pos = jnp.int32(args.prompt_len + i)
            logits, cache = serve(params, cache, tok, pos)
            tok = jnp.argmax(logits[:, -1, :cfg.vocab], axis=-1)[:, None] \
                .astype(jnp.int32)
            generated.append(tok)
        jax.block_until_ready(tok)
        t_decode = time.time() - t0

    out = np.concatenate([np.asarray(t) for t in generated], axis=1)
    tput = args.batch * (args.gen_len - 1) / max(t_decode, 1e-9)
    print(f"[serve] arch={cfg.name} batch={args.batch} "
          f"prefill={t_prefill * 1e3:.1f}ms "
          f"decode={t_decode / max(args.gen_len - 1, 1) * 1e3:.2f}ms/tok "
          f"({tput:.1f} tok/s)")
    print(f"[serve] sample continuation: {out[0, :16].tolist()}")
    return out


if __name__ == "__main__":
    main()
