"""End-to-end training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch phi4-mini-3.8b \
      --reduced --steps 200 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt

``--reduced`` runs the family-faithful tiny config (CPU-friendly);
omit it on a real TPU slice to train the full config over the
production mesh. The loop is the fault-tolerant driver (checkpoint /
restart / straggler watchdog) regardless of scale.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import get_config
from ..data.pipeline import TokenPipeline
from ..optim.adamw import AdamWConfig
from ..runtime.fault_tolerance import FailureInjector, ResilientLoop
from ..train.steps import init_train_state, make_train_step
from .mesh import make_host_mesh, make_production_mesh
from .sharding import batch_pspecs, named, train_state_pspecs


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--fail-at", default="",
                    help="comma-separated steps for failure injection")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = (make_production_mesh() if args.production_mesh
            else make_host_mesh())

    opt = AdamWConfig(lr_peak=args.lr, warmup_steps=min(50, args.steps // 4),
                      decay_steps=args.steps)
    step_fn = make_train_step(cfg, opt)
    state_sh = named(mesh, train_state_pspecs(cfg))
    batch_sh = named(mesh, batch_pspecs(cfg, mesh))

    with mesh:
        jit_step = jax.jit(step_fn, in_shardings=(state_sh, batch_sh),
                           out_shardings=(state_sh, None),
                           donate_argnums=0)
        state = jax.jit(
            lambda k: init_train_state(k, cfg),
            out_shardings=state_sh)(jax.random.PRNGKey(args.seed))

        pipeline = TokenPipeline(cfg, args.batch, args.seq, seed=args.seed)

        class ShardedPipeline:
            def global_batch(self, step):
                return jax.device_put(pipeline.global_batch(step), batch_sh)

        injector = None
        if args.fail_at:
            injector = FailureInjector(
                tuple(int(s) for s in args.fail_at.split(",")))

        loop = ResilientLoop(jit_step, ShardedPipeline(), args.ckpt_dir,
                             ckpt_every=args.ckpt_every, injector=injector)
        t0 = time.time()
        state = loop.run(state, args.steps, state_shardings=state_sh)
        wall = time.time() - t0

    losses = [m["loss"] for m in loop.metrics_log]
    n = max(len(losses) // 10, 1)
    print(f"[train] arch={cfg.name} steps={args.steps} wall={wall:.1f}s "
          f"({wall / max(args.steps, 1) * 1e3:.1f} ms/step) "
          f"restarts={loop.restarts} stragglers={len(loop.watchdog.events)}")
    print(f"[train] loss first10={np.mean(losses[:n]):.4f} "
          f"last10={np.mean(losses[-n:]):.4f}")
    return loop


if __name__ == "__main__":
    main()
