"""Partition rules: parameter/optimizer/activation/cache PartitionSpecs.

The 2D(+pod) strategy:
  * 'model' — tensor/expert parallel: attention heads & head projections,
    MLP hidden dim, MoE expert axis, vocab dim of embed/lm_head.
  * 'data'  — DP for activations AND FSDP for the non-TP dim of every
    large parameter (ZeRO-3-style; GSPMD inserts the all-gathers).
  * 'pod'   — pure DP across pods (batch only; params replicated across
    pods, gradients all-reduced over the inter-pod links).

Rules are by leaf NAME (the param tree is flat enough that names are
unambiguous), so new layer types compose by adding a name entry.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from .mesh import batch_axes

# leaf name -> PartitionSpec WITHOUT the stacked layer axis
_RULES = {
    # embeddings / head
    "embed": P("model", "data"),
    "lm_head": P("data", "model"),
    "final_norm": P(None),
    # attention (GQA)
    "wq": P("data", "model"), "wk": P("data", "model"),
    "wv": P("data", "model"), "wo": P("model", "data"),
    "bq": P("model"), "bk": P("model"), "bv": P("model"),
    # attention (MLA)
    "w_dq": P("data", None), "w_uq": P(None, "model"),
    "w_dkv": P("data", None), "w_kr": P("data", None),
    "w_ukv": P(None, "model"),
    # dense MLP
    "w_gate": P("data", "model"), "w_up": P("data", "model"),
    "w_down": P("model", "data"),
    # MoE (expert axis leads)
    "router": P("data", None),
    "moe/w_gate": P("model", "data", None),
    "moe/w_up": P("model", "data", None),
    "moe/w_down": P("model", None, "data"),
    # mamba
    "in_proj": P("data", "model"), "dt_proj": P("data", None),
    "conv_w": P(None, "model"),
    "out_proj": P("model", "data"), "out_norm": P("model"),
    "A_log": P(None), "dt_bias": P(None), "D": P(None),
    # norms / scales
    "ln1": P(None), "ln2": P(None), "mix_na": P(None), "mix_nm": P(None),
}


def _rule_for(path: str) -> P:
    name = path.split("/")[-1]
    parent = "/".join(path.split("/")[-2:])
    if parent in _RULES:
        return _RULES[parent]
    if name in _RULES:
        return _RULES[name]
    raise KeyError(f"no partition rule for param {path!r}")


def param_pspecs(cfg, *, serve_tp: bool = False) -> dict:
    """PartitionSpec tree matching models.param_shapes(cfg).

    serve_tp=True drops the FSDP ('data') dim from every rule —
    inference has no optimizer state to shard, and TP-only params avoid
    the per-layer all-gather entirely."""
    from ..models.transformer import param_shapes

    def strip_data(spec):
        return P(*(None if a == "data" else a for a in spec))

    def walk(tree, prefix="", stacked=False):
        out = {}
        for k, v in tree.items():
            p = f"{prefix}/{k}" if prefix else k
            if isinstance(v, dict):
                out[k] = walk(v, p, stacked=stacked or k == "layers")
            else:
                spec = _rule_for(p)
                if serve_tp:
                    spec = strip_data(spec)
                if stacked:                      # leading L axis unsharded
                    spec = P(None, *spec)
                out[k] = spec
        return out

    return walk(param_shapes(cfg))


def train_state_pspecs(cfg):
    """TrainState(step, params, m, v) — moments shard like params."""
    from ..train.steps import TrainState
    pp = param_pspecs(cfg)
    return TrainState(step=P(), params=pp, m=pp, v=pp)


def batch_pspecs(cfg, mesh) -> dict:
    b = batch_axes(mesh)
    if getattr(cfg, "batch_2d", False):
        b = b + ("model",)
    spec = {"tokens": P(b, None), "labels": P(b, None)}
    if cfg.n_vision_tokens:
        spec["vision_embeds"] = P(b, None, None)
    return spec


def cache_pspecs(cfg, mesh, *, batch: int) -> dict:
    """Decode-cache specs. Large-batch decode shards batch on the data
    axes and sequence on 'model' (context parallel); batch=1 long-context
    decode shards sequence over EVERY axis."""
    b = batch_axes(mesh)
    data_par = 1
    for a in b:
        data_par *= mesh.shape[a]
    if batch >= data_par:
        bspec, sspec = b, "model"
    else:
        bspec, sspec = None, (*b, "model")
    spec: dict = {}
    if cfg.family != "ssm":
        if cfg.mla is not None:
            spec["kvc"] = P(None, bspec, sspec, None)
            spec["kpe"] = P(None, bspec, sspec, None)
        else:
            spec["k"] = P(None, bspec, sspec, None, None)
            spec["v"] = P(None, bspec, sspec, None, None)
            if getattr(cfg, "kv_cache_dtype", "native") == "int8":
                spec["k_scale"] = P(None, bspec, sspec, None)
                spec["v_scale"] = P(None, bspec, sspec, None)
    if cfg.family in ("ssm", "hybrid"):
        # head_dim (not heads) on 'model': head counts may be odd (25)
        spec["ssm"] = P(None, bspec, None, None, "model")
        spec["conv"] = P(None, bspec, None, "model")
    return spec


def named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
