"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (smoke tests see 1 CPU device; only dryrun.py
forces 512 placeholder host devices).

Topology model (TPU v5e-class):
  single pod : 16 x 16 = 256 chips, axes ("data", "model")
  multi-pod  : 2 x 16 x 16 = 512 chips, axes ("pod", "data", "model")
The "model" axis carries TP/EP/sequence-parallel shards (highest ICI
locality); "data" carries DP + FSDP (optimizer/param shards); "pod" is
pure DP across the slower inter-pod links (gradient all-reduce only).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1x1 mesh over whatever devices exist (tests/examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def batch_axes(mesh) -> tuple:
    """Mesh axes the global batch (or point set) is sharded over."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def n_chips(mesh) -> int:
    return mesh.devices.size
