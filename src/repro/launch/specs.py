"""ShapeDtypeStruct input factories for every (arch × shape) dry-run cell.

Nothing here allocates: full-scale states come from jax.eval_shape over
the real init functions (weak-type-correct, shardable stand-ins).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ArchConfig
from ..models import init_cache, init_params
from ..train.steps import init_train_state
from .mesh import batch_axes
from .sharding import (batch_pspecs, cache_pspecs, param_pspecs,
                       train_state_pspecs)

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _batch_specs(cfg: ArchConfig, b: int, s: int) -> dict:
    spec = {
        "tokens": _sds((b, s), jnp.int32),
        "labels": _sds((b, s), jnp.int32),
    }
    if cfg.n_vision_tokens:
        spec["vision_embeds"] = _sds((b, cfg.n_vision_tokens, cfg.d_model),
                                     cfg.compute_dtype)
    return spec


def _key_spec():
    return _sds((2,), jnp.uint32)


def input_specs(cfg: ArchConfig, shape_name: str, mesh):
    """Returns (args: tuple of ShapeDtypeStructs, in_specs: matching
    PartitionSpec pytrees, out_specs or None, kind)."""
    info = SHAPES[shape_name]
    b, s = info["batch"], info["seq"]
    kind = info["kind"]
    bax = batch_axes(mesh)

    if kind == "train":
        state = jax.eval_shape(
            functools.partial(init_train_state, cfg=cfg), _key_spec())
        batch = _batch_specs(cfg, b, s)
        in_specs = (train_state_pspecs(cfg), batch_pspecs(cfg, mesh))
        out_specs = (train_state_pspecs(cfg), None)
        return (state, batch), in_specs, out_specs, kind

    params = jax.eval_shape(
        functools.partial(init_params, cfg=cfg), _key_spec())
    pspecs = param_pspecs(cfg, serve_tp=getattr(cfg, "serve_tp_params",
                                                False))

    if kind == "prefill":
        batch = {"tokens": _sds((b, s), jnp.int32)}
        bspecs = {"tokens": P(bax, None)}
        if cfg.n_vision_tokens:
            batch["vision_embeds"] = _sds(
                (b, cfg.n_vision_tokens, cfg.d_model), cfg.compute_dtype)
            bspecs["vision_embeds"] = P(bax, None, None)
        cspecs = cache_pspecs(cfg, mesh, batch=b)
        out_specs = ((P(bax, None, "model"), cspecs)
                     if _data_par(mesh, bax) <= b else (None, cspecs))
        return (params, batch), (pspecs, bspecs), out_specs, kind

    # decode
    cache = jax.eval_shape(
        functools.partial(init_cache, cfg, b, s))
    cspecs = cache_pspecs(cfg, mesh, batch=b)
    dpar = _data_par(mesh, bax)
    tok_spec = P(bax, None) if b >= dpar else P(None, None)
    args = (params, cache, _sds((b, 1), jnp.int32), _sds((), jnp.int32))
    in_specs = (pspecs, cspecs, tok_spec, P())
    logits_spec = (P(bax, None, "model") if b >= dpar
                   else P(None, None, "model"))
    out_specs = (logits_spec, cspecs)
    return args, in_specs, out_specs, kind


def _data_par(mesh, bax) -> int:
    n = 1
    for a in bax:
        n *= mesh.shape[a]
    return n


def reduced_cell(cfg: ArchConfig, shape_name: str):
    """Tiny analogue of a cell for CPU integration tests."""
    info = SHAPES[shape_name]
    scale = dataclasses.replace(cfg.reduced())
    return scale, dict(kind=info["kind"], seq=128, batch=4)
