import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver.

For every (arch × shape × mesh) cell: AOT ``jax.jit(...).lower(...)``
with explicit in/out shardings, ``.compile()``, then record
memory_analysis / cost_analysis / collective-bytes into a JSON cache
(results/dryrun/<arch>__<shape>__<mesh>.json). The JSON cache is what
benchmarks/roofline_report.py and EXPERIMENTS.md read.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both [--force]
"""

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from pathlib import Path  # noqa: E402

import jax           # noqa: E402

from ..configs import get_config, list_configs           # noqa: E402
from ..roofline.analysis import (collective_bytes_per_device,  # noqa: E402
                                 roofline)
from ..train.steps import (make_prefill_step, make_serve_step,  # noqa: E402
                           make_train_step)
from .mesh import make_production_mesh, n_chips          # noqa: E402
from .sharding import named                               # noqa: E402
from .specs import SHAPES, input_specs                    # noqa: E402

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def analysis_cfg(cfg, seq: int, n_layers: int):
    """Variant for exact cost accounting: XLA's cost_analysis counts
    while-loop bodies ONCE (verified empirically), so the analysis
    artifact disables every inner scan (query/loss/SSD chunking) and is
    lowered at L=1 and L=2 — the diff is the exact per-layer cost, which
    scales analytically to the real depth. The deliverable artifact (A)
    keeps scan+chunking and proves compile + memory."""
    import dataclasses
    kw = dict(n_layers=n_layers, unroll_layers=True, unroll_chunks=True)
    return dataclasses.replace(cfg, **kw)


def corrected_cost(arch, shape, multi_pod, cfg):
    """(flops, bytes, collective-bytes) per device, trip-count-exact."""
    recs = []
    for L in (1, 2):
        lowered, mesh, c, kind = lower_cell(
            arch, shape, multi_pod,
            cfg_override=analysis_cfg(cfg, SHAPES[shape]["seq"], L))
        compiled = lowered.compile()
        cost = compiled.cost_analysis()
        coll = collective_bytes_per_device(compiled.as_text())
        recs.append({"flops": float(cost.get("flops", 0.0)),
                     "bytes": float(cost.get("bytes accessed", 0.0)),
                     "coll": float(coll["total"])})
    L = cfg.n_layers
    out = {}
    for k in ("flops", "bytes", "coll"):
        per_layer = recs[1][k] - recs[0][k]
        if per_layer < 0:
            # GSPMD occasionally picks different strategies for the L=1
            # and L=2 artifacts; a negative diff is accounting noise.
            # Clamp to the L=1 cost treated as 1 layer's worth.
            per_layer = recs[0][k] / 2
            out.setdefault("clamped", []).append(k)
        out[k] = recs[0][k] + (L - 1) * per_layer
        out[f"{k}_per_layer"] = per_layer
    return out


def lower_cell(arch: str, shape: str, multi_pod: bool, *,
               cfg_override=None):
    cfg = cfg_override or get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    args, in_specs, out_specs, kind = input_specs(cfg, shape, mesh)
    if kind == "train":
        step = make_train_step(cfg)
    elif kind == "prefill":
        step = make_prefill_step(cfg)
    else:
        step = make_serve_step(cfg)
    with mesh:
        jitted = jax.jit(step,
                         in_shardings=named(mesh, in_specs),
                         out_shardings=named(mesh, out_specs))
        lowered = jitted.lower(*args)
    return lowered, mesh, cfg, kind


def run_cell(arch: str, shape: str, multi_pod: bool, *, force=False,
             cfg_override=None, tag: str = "") -> dict:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    out_path = RESULTS / f"{arch}__{shape}__{mesh_name}{tag}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())
    t0 = time.time()
    rec = {"arch": arch, "shape": shape, "mesh": mesh_name, "tag": tag}
    try:
        lowered, mesh, cfg, kind = lower_cell(arch, shape, multi_pod,
                                              cfg_override=cfg_override)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        cost = compiled.cost_analysis()
        try:
            mem = compiled.memory_analysis()
            mem_rec = {
                "argument_size_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_size_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_size_bytes": getattr(mem, "temp_size_in_bytes", None),
                "generated_code_size_bytes": getattr(mem, "generated_code_size_in_bytes", None),
            }
        except Exception as e:  # CPU backend may not implement it
            mem_rec = {"error": str(e)}
        hlo = compiled.as_text()
        coll = collective_bytes_per_device(hlo)
        info = SHAPES[shape]
        corr = corrected_cost(arch, shape, multi_pod, cfg)
        rl = roofline({"flops": corr["flops"],
                       "bytes accessed": corr["bytes"]},
                      corr["coll"], n_chips(mesh), cfg=cfg,
                      kind=kind, batch=info["batch"], seq=info["seq"])
        rec.update(ok=True, kind=kind, lower_s=round(t_lower, 1),
                   compile_s=round(t_compile, 1),
                   cost_raw={k: cost.get(k) for k in
                             ("flops", "bytes accessed", "transcendentals")},
                   cost_corrected=corr,
                   memory=mem_rec, collectives=coll, roofline=rl,
                   hlo_bytes=len(hlo))
    except Exception as e:
        rec.update(ok=False, error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    RESULTS.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(rec, indent=1, default=str))
    status = "OK" if rec.get("ok") else f"FAIL ({rec.get('error', '')[:80]})"
    wall = time.time() - t0
    print(f"[dryrun] {arch:26s} {shape:12s} {mesh_name:8s} "
          f"{wall:6.1f}s  {status}", flush=True)
    return rec


def run_kmeans_cell(multi_pod: bool, *, force=False, tag: str = "",
                    compress: bool = False, opt_sq: bool = False) -> dict:
    """The paper's own workload on the production mesh: distributed
    filtered K-means with points sharded over every chip."""
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from ..configs.kpynq import production as prob
    from .mesh import batch_axes

    mesh_name = "2x16x16" if multi_pod else "16x16"
    out_path = RESULTS / f"kpynq-kmeans__fit__{mesh_name}{tag}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())
    t0 = time.time()
    rec = {"arch": "kpynq-kmeans", "shape": "fit", "mesh": mesh_name,
           "tag": tag}
    try:
        from ..core.distributed import make_fit_sharded

        mesh = make_production_mesh(multi_pod=multi_pod)
        axes = batch_axes(mesh) + ("model",)   # points over EVERY axis
        n_groups = max(prob.k // 10, 1)
        fit = make_fit_sharded(mesh, axes, prob.k, n_groups,
                               prob.max_iters, prob.tol,
                               compress=compress, opt_sq=opt_sq)
        pts = jax.ShapeDtypeStruct(
            (prob.n_points, prob.n_dims), jnp.float32,
            sharding=NamedSharding(mesh, P(axes, None)))
        init = jax.ShapeDtypeStruct(
            (prob.k, prob.n_dims), jnp.float32,
            sharding=NamedSharding(mesh, P()))
        with mesh:
            lowered = jax.jit(fit).lower(pts, init)
            compiled = lowered.compile()
        # exact per-iteration accounting: XLA does not cost while bodies
        # (and, for this shard_map program, called computations either),
        # so lower 1- and 2-iteration unrolled variants, cost them from
        # the HLO TEXT, and diff
        from ..roofline.analysis import hlo_dot_flops, hlo_traffic_bytes
        recs = []
        for it in (1, 2):
            f_u = make_fit_sharded(mesh, axes, prob.k, n_groups,
                                   prob.max_iters, prob.tol,
                                   compress=compress, opt_sq=opt_sq,
                                   unroll_iters=it)
            with mesh:
                c_u = jax.jit(f_u).lower(pts, init).compile()
            txt_u = c_u.as_text()
            recs.append({
                "flops": hlo_dot_flops(txt_u, prob.n_dims),
                "bytes": hlo_traffic_bytes(txt_u),
                "coll": float(collective_bytes_per_device(
                    txt_u)["total"])})
        corr = {}
        for kk in ("flops", "bytes", "coll"):
            per_iter = recs[1][kk] - recs[0][kk]
            corr[kk] = recs[0][kk] + (prob.max_iters - 1) * per_iter
            corr[f"{kk}_per_iter"] = per_iter
        coll = collective_bytes_per_device(compiled.as_text())
        rl = roofline({"flops": corr["flops"],
                       "bytes accessed": corr["bytes"]},
                      corr["coll"], n_chips(mesh))
        # useful work: one dense assignment pass per iteration
        mf = (2.0 * prob.n_points * prob.k * prob.n_dims *
              prob.max_iters) / n_chips(mesh)
        rl["model_flops_per_device"] = mf
        rl["useful_flops_ratio"] = mf / corr["flops"] if corr["flops"] else 0
        t_star = max(rl["t_compute_s"], rl["t_memory_s"],
                     rl["t_collective_s"])
        rl["roofline_fraction"] = (mf / 197e12) / t_star if t_star else 0
        rec["cost_corrected"] = corr
        cost_a = compiled.cost_analysis()
        rec.update(ok=True, kind="kmeans",
                   compile_s=round(time.time() - t0, 1),
                   cost_raw={k: cost_a.get(k) for k in
                             ("flops", "bytes accessed")},
                   collectives=coll, roofline=rl)
    except Exception as e:
        rec.update(ok=False, error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    RESULTS.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(rec, indent=1, default=str))
    print(f"[dryrun] {'kpynq-kmeans':26s} {'fit':12s} {mesh_name:8s} "
          f"{time.time() - t0:6.1f}s  "
          f"{'OK' if rec.get('ok') else 'FAIL (' + rec.get('error', '')[:60] + ')'}",
          flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = list_configs() if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    n_ok = n_fail = 0
    if args.arch in ("all", "kpynq-kmeans"):
        for mp in meshes:
            rec = run_kmeans_cell(mp, force=args.force)
            n_ok += bool(rec.get("ok"))
            n_fail += not rec.get("ok")
        if args.arch == "kpynq-kmeans":
            print(f"[dryrun] done: {n_ok} ok, {n_fail} failed")
            raise SystemExit(1 if n_fail else 0)
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape, mp, force=args.force)
                n_ok += bool(rec.get("ok"))
                n_fail += not rec.get("ok")
    print(f"[dryrun] done: {n_ok} ok, {n_fail} failed")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
