import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver: run tagged optimized variants of the three
chosen cells against their cached baselines and print the
hypothesis -> change -> before -> after log lines for EXPERIMENTS.md.

Cells (selection per the §Perf rubric):
  * hymba-1.5b / train_4k    — worst train-cell roofline fraction
                               (memory-bound: SSD intra-chunk tensors)
  * qwen2-7b / prefill_32k   — most collective-bound (uneven KV-head
                               sharding causes score resharding)
  * kpynq-kmeans / fit       — the paper's own technique at scale
                               (memory-bound: (N, K) distance pass)
"""

import dataclasses  # noqa: E402
import json         # noqa: E402

from ..configs import get_config                      # noqa: E402
from .dryrun import RESULTS, run_cell, run_kmeans_cell  # noqa: E402


def _load(arch, shape, tag=""):
    f = RESULTS / f"{arch}__{shape}__16x16{tag}.json"
    return json.loads(f.read_text()) if f.exists() else None


def _fmt(rec):
    if not rec or not rec.get("ok"):
        return "MISSING/FAIL"
    rl = rec["roofline"]
    return (f"C={rl['t_compute_s']:.3e} M={rl['t_memory_s']:.3e} "
            f"N={rl['t_collective_s']:.3e} dom={rl['bottleneck']} "
            f"frac={rl.get('roofline_fraction', 0):.5f}")


def run_variant(arch, shape, tag, cfg_kw, force=False):
    cfg = dataclasses.replace(get_config(arch), **cfg_kw)
    return run_cell(arch, shape, False, force=force, cfg_override=cfg,
                    tag=f"__{tag}")


def main(force: bool = False):
    print("=== hillclimb: hymba-1.5b train_4k (memory-bound) ===")
    base = _load("hymba-1.5b", "train_4k")
    print("  baseline:", _fmt(base))
    for tag, kw in [
        ("opt_dp2d", dict(batch_2d=True)),
        ("opt_chunk64", dict(ssm=dataclasses.replace(
            get_config("hymba-1.5b").ssm, chunk=64))),
        ("opt_dp2d_chunk64", dict(batch_2d=True,
                                  ssm=dataclasses.replace(
                                      get_config("hymba-1.5b").ssm,
                                      chunk=64))),
        ("opt_dp2d_c64_cp", dict(batch_2d=True, attn_cp=True,
                                 ssm=dataclasses.replace(
                                     get_config("hymba-1.5b").ssm,
                                     chunk=64))),
        # d_state=16 => balanced SSD chunk ~= 16 (intra cost ~ Q/token,
        # inter cost ~ N/token; Q=128 over-pays intra by 8x)
        ("opt_dp2d_c16_cp", dict(batch_2d=True, attn_cp=True,
                                 ssm=dataclasses.replace(
                                     get_config("hymba-1.5b").ssm,
                                     chunk=16))),
        # + triangular causal slicing (~47% less score traffic)
        ("opt_full", dict(batch_2d=True, attn_cp=True, causal_slice=True,
                          ssm=dataclasses.replace(
                              get_config("hymba-1.5b").ssm, chunk=16))),
        # A/B: same minus batch_2d (isolates its resharding collectives)
        ("opt_cp_c16_tri", dict(attn_cp=True, causal_slice=True,
                                ssm=dataclasses.replace(
                                    get_config("hymba-1.5b").ssm,
                                    chunk=16))),
    ]:
        rec = run_variant("hymba-1.5b", "train_4k", tag, kw, force=force)
        print(f"  {tag:18s}:", _fmt(rec))

    print("=== hillclimb: qwen2-7b prefill_32k (collective-bound) ===")
    base = _load("qwen2-7b", "prefill_32k")
    print("  baseline:", _fmt(base))
    for tag, kw in [
        ("opt_cp", dict(attn_cp=True)),
        ("opt_tp", dict(serve_tp_params=True)),
        ("opt_cp_tp", dict(attn_cp=True, serve_tp_params=True)),
        ("opt_tri", dict(causal_slice=True)),
        ("opt_tri_tp", dict(causal_slice=True, serve_tp_params=True)),
        ("opt_tri_cp_tp", dict(causal_slice=True, attn_cp=True,
                               serve_tp_params=True)),
    ]:
        rec = run_variant("qwen2-7b", "prefill_32k", tag, kw, force=force)
        print(f"  {tag:18s}:", _fmt(rec))

    print("=== bonus: qwen2-7b decode_32k (int8 KV cache) ===")
    base = _load("qwen2-7b", "decode_32k")
    print("  baseline:", _fmt(base))
    rec = run_variant("qwen2-7b", "decode_32k", "opt_kv8",
                      dict(kv_cache_dtype="int8", serve_tp_params=True),
                      force=force)
    print(f"  {'opt_kv8_tp':18s}:", _fmt(rec))

    print("=== hillclimb: kpynq-kmeans fit (the paper's technique) ===")
    base = _load("kpynq-kmeans", "fit")
    print("  baseline:", _fmt(base))
    for tag, kw in [
        ("opt_sq", dict(opt_sq=True)),
        ("opt_sq_comp", dict(opt_sq=True, compress=True)),
    ]:
        rec = run_kmeans_cell(False, force=force, tag=f"__{tag}", **kw)
        print(f"  {tag:18s}:", _fmt(rec))


if __name__ == "__main__":
    import sys
    main(force="--force" in sys.argv)
