"""Error-feedback gradient compression (int8) for DP all-reduce.

Used by the shard_map data-parallel wrapper (train.dp_shard) and the
distributed K-means centroid psum: quantise the local contribution to
int8 with a per-tensor scale, all-reduce the dequantised value, and
carry the quantisation residual into the next step (error feedback, so
the bias is corrected rather than accumulated). 4x less ICI traffic on
the gradient all-reduce at the cost of one fp32 residual buffer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray):
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray):
    return q.astype(jnp.float32) * scale


def compress_psum(tree, residual, axis_name):
    """Error-feedback compressed psum over ``axis_name`` (inside
    shard_map). Returns (psummed tree fp32, new residual tree)."""
    def one(x, r):
        xf = x.astype(jnp.float32) + r
        q, scale = quantize_int8(xf)
        deq = dequantize_int8(q, scale)
        new_r = xf - deq
        summed = jax.lax.psum(deq, axis_name)
        return summed, new_r

    flat_x, tdef = jax.tree.flatten(tree)
    flat_r = tdef.flatten_up_to(residual)
    out = [one(x, r) for x, r in zip(flat_x, flat_r)]
    return (tdef.unflatten([o[0] for o in out]),
            tdef.unflatten([o[1] for o in out]))


def init_residual(tree):
    return jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), tree)
