"""Optimizers + schedules + gradient compression."""
from .adamw import AdamWConfig, adamw_update, cosine_lr, global_norm, init_moments

__all__ = ["AdamWConfig", "adamw_update", "cosine_lr", "global_norm",
           "init_moments"]
