"""AdamW with fp32 moments over (possibly bf16) params, pytree-native.

No master fp32 copy: params stay in their storage dtype and the update
is computed in fp32 then cast back — at multi-hundred-B scale the
m/v moments (fully sharded by the FSDP rules) already dominate state
memory; a master copy would add 4 bytes/param and is left as a config
knob for smaller models.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWConfig(NamedTuple):
    lr_peak: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    lr_min: float = 3e-5
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def cosine_lr(step, cfg: AdamWConfig):
    warm = cfg.lr_peak * (step + 1) / cfg.warmup_steps
    t = jnp.clip((step - cfg.warmup_steps) /
                 jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.lr_min + 0.5 * (cfg.lr_peak - cfg.lr_min) * \
        (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_moments(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return jax.tree.map(zeros, params), jax.tree.map(zeros, params)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(grads, m, v, params, step, cfg: AdamWConfig):
    """Returns (new_params, new_m, new_v, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-12))
    lr = cosine_lr(step, cfg)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** (step.astype(jnp.float32) + 1)
    bc2 = 1 - b2 ** (step.astype(jnp.float32) + 1)

    def upd(g, m_, v_, p):
        g = g.astype(jnp.float32) * scale
        nm = b1 * m_ + (1 - b1) * g
        nv = b2 * v_ + (1 - b2) * g * g
        step_ = (nm / bc1) / (jnp.sqrt(nv / bc2) + cfg.eps)
        decay = cfg.weight_decay * p.astype(jnp.float32) \
            if p.ndim >= 2 else 0.0
        np_ = p.astype(jnp.float32) - lr * (step_ + decay)
        return np_.astype(p.dtype), nm, nv

    flat_g, tdef = jax.tree.flatten(grads)
    flat_m = tdef.flatten_up_to(m)
    flat_v = tdef.flatten_up_to(v)
    flat_p = tdef.flatten_up_to(params)
    out = [upd(g, m_, v_, p)
           for g, m_, v_, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, new_m, new_v, {"grad_norm": gnorm, "lr": lr}
