"""Hymba-1.5B [hybrid] — parallel attention + Mamba heads per layer."""
from .base import ArchConfig, MLAConfig, SSMConfig, register

CONFIG = register(ArchConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, head_dim=64,
    d_ff=5504, vocab=32001, rope_theta=1e4,
    ssm=SSMConfig(d_state=16, d_inner=3200, n_heads=25, head_dim=128,
                  n_groups=1, conv_width=4, chunk=128),
))
