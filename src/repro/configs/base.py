"""Architecture config schema + registry.

Every assigned architecture is one frozen ``ArchConfig`` in this package;
``reduced()`` derives the CPU smoke-test variant (same family/topology,
tiny dims). ``input_specs`` lives in launch/specs.py (ShapeDtypeStructs
only — the full configs are never materialised outside the dry-run).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int
    kv_lora_rank: int
    nope_dim: int
    rope_dim: int
    v_dim: int


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int
    d_inner: int
    n_heads: int
    head_dim: int
    n_groups: int = 1
    conv_width: int = 4
    chunk: int = 128


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    qkv_bias: bool = False
    rope_theta: float = 1e4
    # MoE
    n_experts: int = 0
    moe_top_k: int = 0
    moe_capacity_factor: float = 1.25
    # variants
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # frontend stubs
    n_vision_tokens: int = 0    # vlm: precomputed patch embeds per sample
    # execution
    q_chunk: int = 1024
    loss_chunk: int = 1024
    remat: str = "full"         # none | full
    unroll_layers: bool = False  # analysis artifacts: exact HLO costs
    unroll_chunks: bool = False  # analysis: unroll q/loss chunk loops too
    # beyond-paper perf knobs (see EXPERIMENTS.md §Perf)
    attn_cp: bool = False   # context-parallel attention: shard K/V seq on
                            # 'model' instead of (uneven) kv-head sharding
    batch_2d: bool = False  # shard batch over ('data','model') — pure-DP
                            # mode for small models (activation memory /16)
    serve_tp_params: bool = False  # inference: params TP-only (no FSDP
                                   # dim -> no per-layer all-gathers)
    causal_slice: bool = False  # triangular chunking: chunk i attends
                                # keys[: (i+1)*cq] only (~47% less score
                                # traffic; XLA cannot infer this)
    kv_cache_dtype: str = "native"  # 'native' | 'int8' (per-token-head
                                    # scales; halves decode cache reads)
    dtype: str = "bfloat16"
    tie_embeddings: bool = False

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 256 so the vocab dim shards
        evenly over the 16-way 'model' axis with 128-lane alignment
        (MaxText-style padding; padded ids never appear in labels)."""
        return -(-self.vocab // 256) * 256

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    def reduced(self) -> "ArchConfig":
        """Tiny same-family variant for CPU smoke tests."""
        scale = {}
        scale["n_layers"] = min(self.n_layers, 2)
        scale["d_model"] = 64
        n_h = max(min(self.n_heads, 4), 1)
        n_kv = max(min(self.n_kv_heads, n_h), 1)
        if n_h % n_kv:
            n_kv = 1
        scale["n_heads"] = n_h
        scale["n_kv_heads"] = n_kv
        scale["head_dim"] = 16
        scale["d_ff"] = 128 if self.d_ff else 0
        scale["vocab"] = 256
        if self.n_experts:
            scale["n_experts"] = min(self.n_experts, 4)
            scale["moe_top_k"] = min(self.moe_top_k, 2)
        if self.mla is not None:
            scale["mla"] = MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                                     nope_dim=8, rope_dim=8, v_dim=8)
        if self.ssm is not None:
            scale["ssm"] = SSMConfig(d_state=8, d_inner=128, n_heads=4,
                                     head_dim=32, n_groups=1,
                                     conv_width=self.ssm.conv_width,
                                     chunk=8)
        if self.n_vision_tokens:
            scale["n_vision_tokens"] = 8
        scale["q_chunk"] = 32
        scale["loss_chunk"] = 32
        scale["dtype"] = "float32"
        return dataclasses.replace(self, **scale)


_REGISTRY: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    from . import _load_all  # noqa: F401  (populates registry)
    _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list[str]:
    from . import _load_all
    _load_all()
    return sorted(_REGISTRY)
