"""MusicGen-medium [audio] — decoder-only over EnCodec tokens (frontend stubbed\nto a single codebook stream; RoPE replaces sinusoidal PE — noted in DESIGN.md)."""
from .base import ArchConfig, MLAConfig, SSMConfig, register

CONFIG = register(ArchConfig(
    name="musicgen-medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24, head_dim=64,
    d_ff=6144, vocab=2048, rope_theta=1e4,
))
