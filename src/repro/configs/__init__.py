"""Architecture registry: one module per assigned arch + the paper's own."""
from .base import ArchConfig, MLAConfig, SSMConfig, get_config, list_configs

_LOADED = False

ARCH_MODULES = [
    "llava_next_mistral_7b", "llama4_scout_17b_a16e", "qwen3_moe_235b_a22b",
    "mistral_nemo_12b", "minicpm3_4b", "qwen2_7b", "phi4_mini_3_8b",
    "musicgen_medium", "hymba_1_5b", "mamba2_780m",
]


def _load_all():
    global _LOADED
    if _LOADED:
        return
    import importlib
    for mod in ARCH_MODULES:
        importlib.import_module(f"{__name__}.{mod}")
    _LOADED = True


__all__ = ["ArchConfig", "MLAConfig", "SSMConfig", "get_config",
           "list_configs", "ARCH_MODULES"]
