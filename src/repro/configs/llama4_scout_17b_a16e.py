"""Llama-4 Scout 17B-active 16-expert [moe] — early-fusion frontend stubbed."""
from .base import ArchConfig, MLAConfig, SSMConfig, register

CONFIG = register(ArchConfig(
    name="llama4-scout-17b-a16e", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab=202048, rope_theta=5e5,
    n_experts=16, moe_top_k=1,
))
