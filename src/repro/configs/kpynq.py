"""The paper's own workload: K-means problem configs.

``paper_suite`` mirrors the scale range of the six UCI datasets used in
the paper (it evaluates on "large-size, high-dimension" data but the
exact six are unnamed; these spans cover the usual UCI clustering picks
from small (Iris-like) to large (US Census / KDD-cup-like)).
``production`` is the multi-pod-scale problem for the mesh dry-run.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class KMeansProblem:
    name: str
    n_points: int
    n_dims: int
    k: int
    n_groups: int | None = None      # None -> K // 10 heuristic
    max_iters: int = 50
    tol: float = 1e-4


# UCI-like ladder (size x dimensionality spread, as in the paper's table)
paper_suite = [
    KMeansProblem("uci-small",   n_points=4_096,     n_dims=16,  k=32),
    KMeansProblem("uci-medium",  n_points=32_768,    n_dims=32,  k=64),
    KMeansProblem("uci-wide",    n_points=32_768,    n_dims=128, k=64),
    KMeansProblem("uci-large",   n_points=262_144,   n_dims=64,  k=128),
    KMeansProblem("uci-xlarge",  n_points=1_048_576, n_dims=32,  k=256),
    KMeansProblem("uci-highk",   n_points=262_144,   n_dims=32,  k=1024),
]

# Multi-pod scale: points sharded over every chip of the production mesh.
production = KMeansProblem("kpynq-production", n_points=16_777_216,
                           n_dims=128, k=4096, max_iters=20)

smoke = KMeansProblem("kpynq-smoke", n_points=2_048, n_dims=8, k=16,
                      max_iters=10)
