"""LLaVA-NeXT (1.6) Mistral-7B backbone [vlm] — anyres tiling frontend stubbed."""
from .base import ArchConfig, MLAConfig, SSMConfig, register

CONFIG = register(ArchConfig(
    name="llava-next-mistral-7b", family="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=32000, rope_theta=1e6,
    n_vision_tokens=576,  # one 24x24 CLIP grid per sample (anyres stub)
))
