"""Mamba2-780m [ssm] — attention-free SSD (state-space duality)."""
from .base import ArchConfig, MLAConfig, SSMConfig, register

CONFIG = register(ArchConfig(
    name="mamba2-780m", family="ssm",
    n_layers=48, d_model=1536, n_heads=0, n_kv_heads=0, head_dim=0,
    d_ff=0, vocab=50280, rope_theta=1e4,
    ssm=SSMConfig(d_state=128, d_inner=3072, n_heads=48, head_dim=64,
                  n_groups=1, conv_width=4, chunk=128),
))
