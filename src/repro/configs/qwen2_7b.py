"""Qwen2-7B [dense] — GQA with QKV bias."""
from .base import ArchConfig, MLAConfig, SSMConfig, register

CONFIG = register(ArchConfig(
    name="qwen2-7b", family="dense",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, head_dim=128,
    d_ff=18944, vocab=152064, rope_theta=1e6, qkv_bias=True,
))
