"""Qwen3-MoE 235B-A22B [moe] — 128 experts, top-8."""
from .base import ArchConfig, MLAConfig, SSMConfig, register

CONFIG = register(ArchConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, head_dim=128,
    d_ff=1536, vocab=151936, rope_theta=1e6,
    n_experts=128, moe_top_k=8,
))
