"""Mistral-Nemo-Base-2407 12B [dense] — 128k context."""
from .base import ArchConfig, MLAConfig, SSMConfig, register

CONFIG = register(ArchConfig(
    name="mistral-nemo-12b", family="dense",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=131072, rope_theta=1e6,
))
