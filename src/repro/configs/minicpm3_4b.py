"""MiniCPM3-4B [dense] — MLA (multi-head latent attention)."""
from .base import ArchConfig, MLAConfig, SSMConfig, register

CONFIG = register(ArchConfig(
    name="minicpm3-4b", family="dense",
    n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40, head_dim=64,
    d_ff=6400, vocab=73448, rope_theta=1e4,
    mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256,
                  nope_dim=64, rope_dim=32, v_dim=64),
))
