"""repro — KPynq (work-efficient triangle-inequality K-means) rebuilt as
a multi-pod JAX/TPU framework. See README.md / DESIGN.md."""

__version__ = "1.0.0"
