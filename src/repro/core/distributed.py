"""Distributed KPynq: data-parallel filtered K-means under shard_map.

Points are sharded along one (or a flattened set of) mesh axes; bounds
(ub/lb) and assignments live with their shard; centroids are replicated.
Each iteration the only communication is a psum of the (K, D) partial
sums + (K,) counts — exactly the FPGA design's "stream points through,
accumulate centroids centrally" pattern mapped onto ICI collectives
(and the simplified map-reduce framing of Li et al.: map = per-shard
assignment, reduce = the centroid psum). Filtering is per-shard local,
so the work saving composes with parallelism.

Both sharded fits are THIN WRAPPERS over the engine's pass core
(:func:`repro.core.engine.fit_core` — the one candidate-pass loop
implementation): this module contributes ONLY the ``shard_map`` specs,
the psum :class:`~repro.core.engine.Reducer`, and the host-side shard
padding. Exactness fixes in the core land in the local and distributed
paths at once; there is no distributed copy of the iteration.

Two per-shard realisations of the candidate pass:

``backend="compact"`` (default, :func:`make_fit_sharded_engine`)
    The engine's capacity-bucketed two-level compaction
    (``PassCore(backend="ladder")``): each shard carries its own bucket
    level through the ``lax.while_loop`` and switches levels
    shard-locally over a static capacity ladder (``engine.cap_ladders``
    / ``engine.select_bucket``) with the tuned downshift hysteresis —
    no host syncs anywhere in the sharded loop. The convergence test
    rides on the psum'd centroid sums (every shard sees the same
    drift, so the while conds agree), and the ``EvalCount`` work
    counter is psum'd at the end.
``backend="dense"`` (:func:`make_fit_sharded`)
    The masked-dense pass over every shard point
    (``PassCore(backend="oracle")``, exact, no skipped FLOPs) — the
    oracle the compact path is tested against, and the AOT-lowering
    target of the production-mesh dry-run.

Optional int8 compression of the psum payload (``compress=True``)
applies to the (K, D) partial-sums tensor only (counts, sample weights
and scalars stay exact) — the gradient-compression analogue for the
centroid sums, realised inside ``Reducer.sums``.

``sample_weight``: per-point weights shard with their points and enter
the psum'd sums/counts and the inertia through the core — every
reduction payload is weighted with the SAME single implementation as
the local fit.

Uneven shard sizes are handled by padding to the shard lattice with
sentinel rows (``assignment = K``, ``ub = 0``, ``lb = +inf``, weight 0
when weighted): the sentinel drops out of every ``segment_sum`` and the
zero/inf bounds keep padded rows filtered forever, so they cost no
candidate work and touch no statistics.

:func:`make_stream_bounds_sharded` / :func:`make_stream_update_sharded`
are the sharded instantiations of ``engine.stream_bounds`` /
``engine.stream_step`` — one global mini-batch split over the mesh,
candidate pass per shard, psum'd batch sums/counts feeding the decayed
EMA — driven by ``repro.streaming.StreamingKMeans(mesh=...)``.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# shard_map moved out of jax.experimental (and check_rep was renamed
# check_vma) across jax generations; support both so `import repro.core`
# works everywhere. The flag disables the replication/vma check: psum
# outputs are value-replicated but the static analysis cannot prove it
# through the while_loop carry.
try:
    from jax.experimental.shard_map import shard_map as _shard_map
    _SHARD_MAP_KW = {"check_rep": False}
except ImportError:                      # jax >= 0.7
    _shard_map = jax.shard_map
    _SHARD_MAP_KW = {"check_vma": False}

from ..obs import ring as _obs_ring
from ..obs.metrics import normalize_obs
from . import engine as _engine
from .engine import (DEFAULT_CONFIG, EngineConfig, EngineStats, PassCore,
                     Reducer, StreamStepOut, build_group_tables,
                     cap_ladders, stream_bounds)
from .kmeans import KMeansResult, group_centroids


def make_fit_sharded(mesh: Mesh, axes, k: int, n_groups: int,
                     max_iters: int, tol: float, compress: bool = False,
                     opt_sq: bool = True, unroll_iters: int = 0,
                     weighted: bool = False, ring_iters: int = 0):
    """Build the jittable shard_map K-means fit with the masked-dense
    per-shard pass (AOT-lowerable for the production-mesh dry-run;
    executed by distributed_yinyang). The body is
    ``engine.fit_core(core=PassCore(backend="oracle", reducer=psum))``
    — no loop code lives here.

    ``opt_sq`` (default True, §Perf optimization): run the masked
    min/argmin pass on SQUARED distances (monotone, so results are
    identical) and sqrt only the reduced outputs. False exists for the
    dry-run's A/B cost analysis only — every driver runs True.

    ``weighted=True`` adds a per-point ``sample_weight`` argument,
    sharded with the points.

    unroll_iters>0: replace the while_loop with exactly that many python
    iterations of the SAME body — analysis artifacts only (XLA
    cost_analysis does not descend into while bodies; the N-vs-(N-1)
    unrolled diff gives the exact per-iteration cost).

    ``ring_iters>0`` carries the per-iteration telemetry ring through
    the loop (``repro.obs.ring``); the sixth output is the PER-SHARD
    ring stack (S, ring_iters, C), pre-reduction — join with
    ``obs.ring.reduce_shard_rings``."""
    axes = tuple(axes)
    pspec = P(axes, None)
    core = PassCore(backend="oracle", k=k, n_groups=n_groups,
                    opt_sq=opt_sq, ring_iters=ring_iters,
                    reducer=Reducer(axes=axes, compress=compress))
    out_specs = (P(None, None), P(axes), P(), P(), P(),
                 P(axes, None, None))

    in_specs = (pspec, P(None, None)) + ((P(axes),) if weighted else ())

    @functools.partial(_shard_map, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, **_SHARD_MAP_KW)
    def fit_sharded(local_points, init_c, *rest):
        weights = rest[0] if weighted else None
        groups = group_centroids(init_c, n_groups)
        dummy_members = jnp.full((n_groups, 1), -1, jnp.int32)
        dummy_gsize = jnp.zeros((n_groups,), jnp.float32)
        if unroll_iters > 0:
            out = _engine.fit_core_unrolled(
                local_points, init_c, groups, dummy_members, dummy_gsize,
                core=core, n_iters=unroll_iters, weights=weights)
        else:
            out = _engine.fit_core(
                local_points, init_c, groups, dummy_members, dummy_gsize,
                core=core, max_iters=max_iters, tol=tol, weights=weights)
        # ring stays shard-local: add the leading shard axis the
        # out_spec concatenates over
        return out[:5] + (out[5][None],)

    return fit_sharded


def make_fit_sharded_engine(mesh: Mesh, axes, k: int, n_groups: int,
                            max_iters: int, tol: float, *, shard_n: int,
                            compress: bool = False,
                            config: EngineConfig | None = None,
                            max_branches: int = 12,
                            weighted: bool = False, ring_iters: int = 0):
    """Build the compact (capacity-bucketed) sharded fit.

    Returns a shard_map'd ``fit(local_points, valid[, weights], init_c,
    groups, members, gsize) -> (centroids, assignments, n_iters, evals,
    inertia, shard_rings)`` where ``valid`` masks sentinel padding rows
    (see module
    docstring), ``groups`` is the (K,) centroid->group map and
    ``members``/``gsize`` the host-built group tables
    (``engine.build_group_tables`` — built OUTSIDE the sharded program,
    so the per-point group buckets use the true ``Lmax``, not the K
    upper bound).

    The body is ``engine.fit_core`` at a ``PassCore(backend="ladder",
    reducer=psum)``: the engine's split-loop construction with the
    bucket machinery fully in-trace — each shard carries
    ``(level_n, level_g)`` through the while_loop and transitions via
    ``engine.select_bucket`` using its OWN candidate count / group
    high-water — per-shard work-proportional capacities with zero host
    round trips. ``cfg.min_cap`` floors the ladder;
    ``cfg.down_n``/``down_g`` set the downshift hysteresis;
    ``cfg.chunk`` and ``cfg.group_gather_factor`` pick each branch's
    gather-vs-GEMM crossover; ``cfg.refresh_in_pass`` places the
    own-distance refresh (full-shard rowwise vs on the compacted
    survivor buffer).

    ``ring_iters>0`` enables the per-iteration telemetry ring; the
    sixth output stacks the PER-SHARD rings (S, ring_iters, C) —
    shard-local candidate counts / evals / ladder levels, the raw
    material for the straggler watchdog and skew gauges.
    """
    axes = tuple(axes)
    cfg = config or DEFAULT_CONFIG
    cap_ns, cap_gs = cap_ladders(shard_n, n_groups, min_cap=cfg.min_cap,
                                 max_branches=max_branches)
    core = PassCore.from_config(
        cfg, backend="ladder", k=k, n_groups=n_groups,
        reducer=Reducer(axes=axes, compress=compress),
        cap_ns=cap_ns, cap_gs=cap_gs, ring_iters=ring_iters)
    pspec = P(axes, None)
    out_specs = (P(None, None), P(axes), P(), P(), P(),
                 P(axes, None, None))

    in_specs = (pspec, P(axes)) + ((P(axes),) if weighted else ()) + \
        (P(None, None), P(None), P(None, None), P(None))

    @functools.partial(_shard_map, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, **_SHARD_MAP_KW)
    def fit_sharded(local_points, valid, *rest):
        weights, rest = (rest[0], rest[1:]) if weighted else (None, rest)
        init_c, groups, members, gsize = rest
        out = _engine.fit_core(
            local_points, init_c, groups, members, gsize, core=core,
            max_iters=max_iters, tol=tol, weights=weights, valid=valid)
        return out[:5] + (out[5][None],)

    return fit_sharded


def _mesh_shards(mesh: Mesh, axes) -> int:
    return int(np.prod([mesh.shape[a] for a in axes], dtype=np.int64))


def make_mesh(shards: int, axis: str = "data", devices=None) -> Mesh:
    """A 1-D data mesh over the first ``shards`` devices — the helper
    every driver (and the elastic restart path, which rebuilds a mesh
    of a DIFFERENT size around one checkpoint) uses instead of
    hand-rolling ``Mesh(np.array(jax.devices()[:n]), ...)``."""
    devices = list(jax.devices() if devices is None else devices)
    if shards > len(devices):
        raise ValueError(
            f"requested {shards} shards but only {len(devices)} "
            f"devices are available")
    return Mesh(np.array(devices[:shards]), (axis,))


# Builder memos: a fresh shard_map closure is a fresh jit cache key, so
# without these every distributed_yinyang call would re-trace AND
# re-compile the whole sharded program (the compact ladder compiles one
# pass instance per bucket level — seconds of XLA time on CPU).
@functools.lru_cache(maxsize=64)
def _jitted_fit_dense(mesh: Mesh, axes, k, n_groups, max_iters, tol,
                      compress, weighted, ring_iters=0):
    return jax.jit(make_fit_sharded(mesh, axes, k, n_groups, max_iters,
                                    tol, compress, weighted=weighted,
                                    ring_iters=ring_iters))


@functools.lru_cache(maxsize=64)
def _jitted_fit_engine(mesh: Mesh, axes, k, n_groups, max_iters, tol,
                       shard_n, compress, config, max_branches, weighted,
                       ring_iters=0):
    return jax.jit(make_fit_sharded_engine(
        mesh, axes, k, n_groups, max_iters, tol, shard_n=shard_n,
        compress=compress, config=config, max_branches=max_branches,
        weighted=weighted, ring_iters=ring_iters))


def _pad_sharded(arr_np: np.ndarray, shards: int):
    """Pad (N, ...) to a multiple of ``shards`` rows; returns
    ``(padded, valid bool mask)``."""
    n = len(arr_np)
    n_pad = (-n) % shards
    valid = np.arange(n + n_pad) < n
    if n_pad:
        pad = np.zeros((n_pad,) + arr_np.shape[1:], arr_np.dtype)
        arr_np = np.concatenate([arr_np, pad], axis=0)
    return arr_np, valid


def _sharded_stats(backend, shard_rings, n_iters, *, n, k, cfg, obs_cfg,
                   watchdog) -> EngineStats:
    """Build the serializable :class:`EngineStats` of one sharded fit
    from its drained per-shard rings; feed the straggler watchdog and
    publish the skew gauge when configured. Host python on fetched
    values — runs only under ``return_stats``/``obs``."""
    shard_rings = np.asarray(jax.device_get(shard_rings))
    shard_rings = shard_rings[:, :n_iters + 1]            # trim to fit
    ring = _obs_ring.reduce_shard_rings(shard_rings)
    skew = _obs_ring.shard_skew(shard_rings)
    stats = EngineStats(
        backend=backend, n_iters=n_iters, host_syncs=1, n_points=n,
        config=cfg.to_dict() if cfg is not None else {},
        ring=ring, init_evals=float(n) * k, shard_rings=shard_rings,
        shard_skew=skew, caps_history=_obs_ring.caps_from_ring(ring))
    per_shard_work = shard_rings[:, :, _obs_ring.COL_EVALS]    # (S, R)
    if watchdog is not None:
        for t in range(per_shard_work.shape[1]):
            watchdog.observe_shards(t, per_shard_work[:, t])
    if obs_cfg is not None:
        reg = obs_cfg.resolve_registry()
        labels = {"backend": backend}
        hist = reg.histogram("dist_shard_skew",
                             "per-iteration max/mean work skew",
                             labels=labels,
                             buckets=(1.0, 1.1, 1.25, 1.5, 2.0, 4.0, 8.0))
        for s in skew:
            hist.observe(float(s))
        reg.gauge("dist_last_shard_skew", "final-iteration work skew",
                  labels=labels).set(float(skew[-1]) if len(skew) else 1.0)
        reg.gauge("dist_last_n_iters", "iterations of the last sharded "
                  "fit", labels=labels).set(float(n_iters))
        reg.log_event("distributed_fit", backend=backend,
                      n_iters=n_iters, n_points=n,
                      shards=int(shard_rings.shape[0]),
                      telemetry=stats.telemetry())
    return stats


def distributed_yinyang(points, init_centroids, mesh: Mesh,
                        axes: Sequence[str] = ("data",),
                        n_groups: int | None = None,
                        max_iters: int = 100, tol: float = 1e-4,
                        compress: bool = False, backend: str = "compact",
                        config: EngineConfig | None = None,
                        tune: str = "auto",
                        max_branches: int = 12,
                        sample_weight=None, return_stats: bool = False,
                        obs=None, watchdog=None):
    """Run filtered K-means with points sharded over ``axes`` of ``mesh``.

    ``backend="compact"`` (default) runs the engine's two-level
    capacity-bucketed compaction per shard (see
    :func:`make_fit_sharded_engine`); ``"dense"`` keeps the masked-dense
    per-shard pass (exact oracle; requires N divisible by the shard
    count). Both are instantiations of the SAME
    :func:`repro.core.engine.fit_core`. ``tune`` consults the
    per-(platform, N, K, D, shards) tuning cache for the compact body's
    capacities/crossovers (``"force"`` runs the measured sharded search
    on a miss — see :func:`repro.tune.autotune` ``shards=``);
    ``config`` pins them explicitly.

    ``sample_weight``: optional (N,) per-point weights, sharded with
    their points (weighted psum'd sums/counts + weighted inertia; the
    int8 ``compress`` payload stays the (K, D) sums only).

    ``points`` may be a host array (it is sharded — and, on the compact
    path, padded to the shard lattice — on entry) or an already-sharded
    jax.Array with the right layout.

    ``return_stats=True`` returns ``(result, EngineStats)`` with the
    drained telemetry: the reduced per-iteration ring, the raw
    per-shard ``shard_rings`` and the per-iteration ``shard_skew``
    (max/mean work imbalance — the straggler signal under lockstep
    SPMD). ``obs`` additionally publishes skew gauges and a
    ``distributed_fit`` event to the metrics registry
    (:mod:`repro.obs`); ``watchdog`` feeds each iteration's per-shard
    work into a :class:`repro.runtime.StragglerWatchdog` via
    ``observe_shards``. Enabling any of these changes dispatch only —
    results stay bit-identical.
    """
    if backend not in ("compact", "dense"):
        raise ValueError(f"unknown distributed backend {backend!r}; "
                         f"expected 'compact' or 'dense'")
    if tune not in ("auto", "off", "force"):
        raise ValueError(f"unknown tune mode {tune!r}; expected "
                         f"'auto', 'off' or 'force'")
    k = init_centroids.shape[0]
    if n_groups is None:
        n_groups = max(k // 10, 1)
    n_groups = int(min(n_groups, k))
    axes = tuple(axes)
    shards = _mesh_shards(mesh, axes)
    init_c = jnp.asarray(init_centroids, jnp.float32)
    weighted = sample_weight is not None
    w_np = None if sample_weight is None else \
        np.asarray(jax.device_get(sample_weight), np.float32)
    obs_cfg = normalize_obs(obs)
    want_stats = return_stats or obs_cfg is not None or \
        watchdog is not None
    ring_iters = int(max_iters) + 1 if want_stats else 0

    shard = NamedSharding(mesh, P(axes, None))
    shard1 = NamedSharding(mesh, P(axes))
    repl = NamedSharding(mesh, P())

    if backend == "dense":
        n = points.shape[0]
        if n % shards:
            raise ValueError(
                f"backend='dense' needs N ({n}) divisible by the shard "
                f"count ({shards}); use backend='compact' for uneven "
                f"shards")
        fit_sharded = _jitted_fit_dense(mesh, axes, k, n_groups,
                                        int(max_iters), float(tol),
                                        bool(compress), weighted,
                                        ring_iters)
        points = jax.device_put(points, shard)
        init_d = jax.device_put(init_c, repl)
        args = (points, init_d)
        if weighted:
            args = (points, init_d,
                    jax.device_put(jnp.asarray(w_np), shard1))
        c, a, i, evals, inertia, rings = fit_sharded(*args)
        result = KMeansResult(c, a, i, evals, inertia)
        if not want_stats:
            return result
        stats = _sharded_stats("dense", rings, int(i), n=n, k=k,
                               cfg=config, obs_cfg=obs_cfg,
                               watchdog=watchdog)
        return (result, stats) if return_stats else result

    n, d = points.shape
    if n % shards:
        # uneven: materialise on host once to append the sentinel rows
        pts_in, valid_np = _pad_sharded(
            np.asarray(jax.device_get(points), np.float32), shards)
        if weighted:
            w_np, _ = _pad_sharded(w_np, shards)   # pad rows: weight 0
    else:
        # no padding needed: device-resident arrays stay on device
        # (jnp.asarray is a no-op for committed f32 arrays)
        pts_in = jnp.asarray(points, jnp.float32)
        valid_np = np.ones((n,), bool)
    shard_n = len(pts_in) // shards
    cfg = _resolve_sharded_config(
        points, init_c, mesh, axes, shard_n=shard_n, k=k, d=d,
        shards=shards, config=config, tune=tune, n_groups=n_groups,
        max_iters=int(max_iters), tol=float(tol))

    # group map + tables, built once on the host (true Lmax)
    groups = group_centroids(init_c, n_groups)
    groups_np = np.asarray(jax.device_get(groups))
    members, gsize = build_group_tables(groups_np, n_groups)

    fit_sharded = _jitted_fit_engine(
        mesh, axes, k, n_groups, int(max_iters), float(tol), shard_n,
        bool(compress), cfg, int(max_branches), weighted, ring_iters)
    args = [jax.device_put(pts_in, shard),
            jax.device_put(valid_np, shard1)]
    if weighted:
        args.append(jax.device_put(jnp.asarray(w_np), shard1))
    args += [jax.device_put(init_c, repl),
             jax.device_put(groups, repl),
             jax.device_put(members, repl),
             jax.device_put(gsize, repl)]
    c, a, i, evals, inertia, rings = fit_sharded(*args)
    result = KMeansResult(c, a[:n], i, evals, inertia)
    if not want_stats:
        return result
    stats = _sharded_stats("compact", rings, int(i), n=n, k=k, cfg=cfg,
                           obs_cfg=obs_cfg, watchdog=watchdog)
    return (result, stats) if return_stats else result


def _resolve_sharded_config(points, init_c, mesh, axes, *, shard_n, k, d,
                            shards, config, tune, n_groups, max_iters,
                            tol) -> EngineConfig:
    """Config precedence for the compact sharded fit: explicit
    ``config`` > tuned ``...|sS`` cache entry > (``tune="force"`` only)
    a fresh measured sharded search over THIS mesh > the single-device
    entry for the per-shard shape > defaults."""
    if config is not None:
        return config
    if tune == "off":
        return DEFAULT_CONFIG
    from .. import tune as _tune
    cfg = _tune.lookup(n=shard_n, k=k, d=d, shards=shards)
    if cfg is None and tune == "force":
        cfg = _tune.autotune(
            jnp.asarray(points, jnp.float32)[:shard_n], init_c,
            n_groups=n_groups, max_iters=max_iters, tol=tol,
            shards=shards, mesh=mesh, axes=axes)
    if cfg is None:
        cfg = _tune.lookup(n=shard_n, k=k, d=d)
    return cfg or DEFAULT_CONFIG


# --------------------------------------------------------------------------
# sharded streaming steps (driven by repro.streaming.StreamingKMeans)
# --------------------------------------------------------------------------

def make_stream_bounds_sharded(mesh: Mesh, axes: Sequence[str] = ("data",)):
    """Sharded analogue of ``engine.stream_bounds``: the point-level
    filter over carried (drift-inflated) bounds, per shard of one
    global mini-batch. Returns a jitted ``(points, centroids, assign,
    ub, lb) -> (ub_t, need, max_shard_cand, tightened)`` where
    ``max_shard_cand`` is the pmax'd PER-SHARD candidate count — the
    number the caller's static ``cap_n`` must cover."""
    axes = tuple(axes)

    @functools.partial(
        _shard_map, mesh=mesh,
        in_specs=(P(axes, None), P(None, None), P(axes), P(axes),
                  P(axes, None)),
        out_specs=(P(axes), P(axes), P(), P()),
        **_SHARD_MAP_KW,
    )
    def bounds(points, centroids, assign, ub, lb):
        ub_t, need, n_cand, n_tight = stream_bounds(points, centroids,
                                                    assign, ub, lb)
        return (ub_t, need, jax.lax.pmax(n_cand, axes),
                jax.lax.psum(n_tight, axes))

    return jax.jit(bounds)


def make_stream_update_sharded(mesh: Mesh, axes, *, k: int, n_groups: int,
                               cap_n: int, cap_g: int, chunk: int = 2048,
                               group_gather_factor: int = 4,
                               compress: bool = False,
                               weighted: bool = False):
    """Sharded instantiation of ``engine.stream_step``: one global
    mini-batch split over the mesh, the SAME step body per shard with a
    psum :class:`~repro.core.engine.Reducer` — the reduced batch
    sums/counts make the decayed EMA (and drift) replicated, and the
    scalar telemetry is psum'd/pmax'd by the reducer inside the step.
    ``cap_n`` must cover the max PER-SHARD candidate count (the caller
    syncs it via :func:`make_stream_bounds_sharded`). Returns a jitted
    function with the :class:`~repro.core.engine.StreamStepOut` result;
    ``assignments``/``ub``/``lb`` come back sharded along ``axes``
    (gathered to the global batch on read). ``compress=True``
    int8-compresses the (K, D) partial-sums psum payload only.
    ``weighted=True`` adds a sharded per-point ``weights`` argument."""
    axes = tuple(axes)
    core = PassCore(backend="compact", k=k, n_groups=n_groups,
                    cap_n=cap_n, cap_g=cap_g, chunk=chunk,
                    group_gather_factor=group_gather_factor,
                    reducer=Reducer(axes=axes, compress=compress))
    out_specs = StreamStepOut(
        P(None, None), P(None), P(axes), P(axes), P(axes, None),
        P(), P(), P(None), P(None), P(None), P())
    base_specs = (P(axes, None), P(None, None), P(None), P(), P(None),
                  P(None, None), P(None), P(axes), P(axes), P(axes, None),
                  P(axes))

    in_specs = base_specs + ((P(axes),) if weighted else ())

    @functools.partial(_shard_map, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, **_SHARD_MAP_KW)
    def update(points, centroids, counts, decay, groups, members,
               gsize, assignments, ub_t, lb, need, *rest):
        weights = rest[0] if weighted else None
        return _engine.stream_step(
            points, centroids, counts, decay, groups, members, gsize,
            assignments, ub_t, lb, need, weights, core=core)

    return jax.jit(update)
