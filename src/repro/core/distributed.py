"""Distributed KPynq: data-parallel filtered K-means under shard_map.

Points are sharded along one (or a flattened set of) mesh axes; bounds
(ub/lb) and assignments live with their shard; centroids are replicated.
Each iteration the only communication is a psum of the (K, D) partial
sums + (K,) counts + scalar drift — exactly the FPGA design's
"stream points through, accumulate centroids centrally" pattern mapped
onto ICI collectives. Filtering is per-shard local, so the work saving
composes with parallelism.

Optional int8 error-feedback compression of the psum payload
(``compress=True``) implements the gradient-compression analogue for the
centroid partial sums.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .distances import pairwise_dists, rowwise_dists
from .kmeans import (FilterState, KMeansResult, _init_filter_state,
                     group_centroids, update_centroids)


def _psum_maybe_compressed(x: jnp.ndarray, axes, compress: bool):
    if not compress:
        return jax.lax.psum(x, axes)
    # Error-feedback-free single-shot int8: scale by per-tensor absmax.
    # Exact enough for centroid sums (relative error ~1/127) and the
    # error is self-correcting across Lloyd iterations; tests check
    # convergence to the same inertia ballpark.
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return jax.lax.psum(deq, axes)


def _local_update_sums(points, assignments, k):
    pts = points.astype(jnp.float32)
    sums = jax.ops.segment_sum(pts, assignments, num_segments=k)
    counts = jax.ops.segment_sum(jnp.ones((pts.shape[0],), jnp.float32),
                                 assignments, num_segments=k)
    return sums, counts


def make_fit_sharded(mesh: Mesh, axes, k: int, n_groups: int,
                     max_iters: int, tol: float, compress: bool = False,
                     opt_sq: bool = False, unroll_iters: int = 0):
    """Build the jittable shard_map K-means fit (AOT-lowerable for the
    production-mesh dry-run; executed by distributed_yinyang).

    opt_sq=True (§Perf optimization): run the masked min/argmin pass on
    SQUARED distances (monotone, so results are identical) and sqrt only
    the (N,) / (N,G) reduced outputs — removes a full (N, K) sqrt pass
    and its HBM round-trip per iteration.

    unroll_iters>0: replace the while_loop with exactly that many python
    iterations — analysis artifacts only (XLA cost_analysis does not
    descend into while bodies; the N-vs-(N-1) unrolled diff gives the
    exact per-iteration cost)."""
    axes = tuple(axes)
    pspec = P(axes, None)

    @functools.partial(
        jax.shard_map, mesh=mesh,
        in_specs=(pspec, P(None, None)),
        out_specs=(P(None, None), P(axes), P(), P(), P()),
        # psum outputs are value-replicated but the static vma analysis
        # cannot prove it through the while_loop carry; disable the check
        check_vma=False,
    )
    def fit_sharded(local_points, init_c):
        groups = group_centroids(init_c, n_groups)
        n_local = local_points.shape[0]

        # replicated init assignment pass (local points only)
        state0 = _init_filter_state(local_points, init_c, groups, n_groups)

        def cond(state):
            return jnp.logical_and(state.iteration < max_iters,
                                   state.shift > tol)

        def body(state: FilterState):
            # ---- local filtered assignment (same math as kmeans.py) ----
            rows = jnp.arange(n_local)
            sums, counts = _local_update_sums(local_points,
                                              state.assignments, k)
            sums = _psum_maybe_compressed(sums, axes, compress)
            counts = jax.lax.psum(counts, axes)
            safe = jnp.maximum(counts, 1.0)[:, None]
            new_c = jnp.where(counts[:, None] > 0, sums / safe,
                              state.centroids)

            drift = jnp.linalg.norm(new_c - state.centroids, axis=-1)
            group_drift = jax.ops.segment_max(drift, groups,
                                              num_segments=n_groups)
            shift = jnp.max(drift)

            ub = state.ub + drift[state.assignments]
            lb = jnp.maximum(state.lb - group_drift[None, :], 0.0)
            glb = jnp.min(lb, axis=1)
            maybe = ub > glb
            d_own = rowwise_dists(local_points, new_c[state.assignments])
            ub_t = jnp.where(maybe, d_own, ub)
            need = ub_t > glb
            evals = state.distance_evals + jnp.sum(maybe.astype(jnp.float32))

            group_need = need[:, None] & (lb < ub_t[:, None])
            cand = group_need[:, groups]
            evals = evals + jnp.sum(cand.astype(jnp.float32))

            if opt_sq:
                from .distances import pairwise_sq_dists
                d2 = jnp.where(cand, pairwise_sq_dists(local_points, new_c),
                               jnp.inf)
                best_other = jnp.argmin(d2, axis=1).astype(jnp.int32)
                best_other_d = jnp.sqrt(jnp.min(d2, axis=1))
                d_excl = d2  # sqrt applied after the segment reduction
            else:
                d_all = pairwise_dists(local_points, new_c)
                d_cand = jnp.where(cand, d_all, jnp.inf)
                best_other = jnp.argmin(d_cand, axis=1).astype(jnp.int32)
                best_other_d = jnp.min(d_cand, axis=1)
            new_assign = jnp.where(best_other_d < ub_t, best_other,
                                   state.assignments)
            new_ub = jnp.minimum(ub_t, best_other_d)

            if opt_sq:
                d_excl = d_excl.at[rows, new_assign].set(jnp.inf)
                lb_comp = jnp.sqrt(jax.ops.segment_min(
                    d_excl.T, groups, num_segments=n_groups)).T
            else:
                d_excl = d_cand.at[rows, new_assign].set(jnp.inf)
                lb_comp = jax.ops.segment_min(d_excl.T, groups,
                                              num_segments=n_groups).T
            new_lb = jnp.where(group_need, lb_comp, lb)
            changed = best_other_d < ub_t
            old_group = groups[state.assignments]
            new_lb = new_lb.at[rows, old_group].min(
                jnp.where(changed, ub_t, jnp.inf))

            return FilterState(state.iteration + 1, new_c, new_assign,
                               new_ub, new_lb, shift, evals)

        if unroll_iters > 0:
            state = state0
            for _ in range(unroll_iters):
                state = body(state)
        else:
            state = jax.lax.while_loop(cond, body, state0)
        d = rowwise_dists(local_points, state.centroids[state.assignments])
        inertia = jax.lax.psum(jnp.sum(d * d), axes)
        evals = jax.lax.psum(state.distance_evals, axes)
        return (state.centroids, state.assignments, state.iteration,
                evals, inertia)

    return fit_sharded


def distributed_yinyang(points, init_centroids, mesh: Mesh,
                        axes: Sequence[str] = ("data",),
                        n_groups: int | None = None,
                        max_iters: int = 100, tol: float = 1e-4,
                        compress: bool = False) -> KMeansResult:
    """Run filtered K-means with points sharded over ``axes`` of ``mesh``.

    ``points`` may be a host array (it is sharded on entry) or already a
    sharded jax.Array with the right layout.
    """
    k = init_centroids.shape[0]
    if n_groups is None:
        n_groups = max(k // 10, 1)
    n_groups = int(min(n_groups, k))
    axes = tuple(axes)
    fit_sharded = make_fit_sharded(mesh, axes, k, n_groups, max_iters,
                                   tol, compress)
    points = jax.device_put(points, NamedSharding(mesh, P(axes, None)))
    init_c = jax.device_put(init_centroids.astype(jnp.float32),
                            NamedSharding(mesh, P()))
    c, a, i, evals, inertia = jax.jit(fit_sharded)(points, init_c)
    return KMeansResult(c, a, i, evals, inertia)
