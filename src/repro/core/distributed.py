"""Distributed KPynq: data-parallel filtered K-means under shard_map.

Points are sharded along one (or a flattened set of) mesh axes; bounds
(ub/lb) and assignments live with their shard; centroids are replicated.
Each iteration the only communication is a psum of the (K, D) partial
sums + (K,) counts + scalar drift — exactly the FPGA design's
"stream points through, accumulate centroids centrally" pattern mapped
onto ICI collectives. Filtering is per-shard local, so the work saving
composes with parallelism.

The per-shard iteration is the ENGINE's step (``engine.move_and_bounds``
with a psum reduction hook + ``engine.dense_candidate_pass``) — one
implementation of the filter math shared by the local and distributed
paths, so exactness fixes land in both at once.

Optional int8 error-feedback compression of the psum payload
(``compress=True``) implements the gradient-compression analogue for the
centroid partial sums.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# shard_map moved out of jax.experimental (and check_rep was renamed
# check_vma) across jax generations; support both so `import repro.core`
# works everywhere. The flag disables the replication/vma check: psum
# outputs are value-replicated but the static analysis cannot prove it
# through the while_loop carry.
try:
    from jax.experimental.shard_map import shard_map as _shard_map
    _SHARD_MAP_KW = {"check_rep": False}
except ImportError:                      # jax >= 0.7
    _shard_map = jax.shard_map
    _SHARD_MAP_KW = {"check_vma": False}

from .distances import row_norms_sq, rowwise_dists
from .engine import dense_candidate_pass, move_and_bounds
from .kmeans import (FilterState, KMeansResult, _init_filter_state,
                     group_centroids)


def _psum_maybe_compressed(x: jnp.ndarray, axes, compress: bool):
    if not compress:
        return jax.lax.psum(x, axes)
    # Error-feedback-free single-shot int8: scale by per-tensor absmax.
    # Exact enough for centroid sums (relative error ~1/127) and the
    # error is self-correcting across Lloyd iterations; tests check
    # convergence to the same inertia ballpark.
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return jax.lax.psum(deq, axes)


def make_fit_sharded(mesh: Mesh, axes, k: int, n_groups: int,
                     max_iters: int, tol: float, compress: bool = False,
                     opt_sq: bool = True, unroll_iters: int = 0):
    """Build the jittable shard_map K-means fit (AOT-lowerable for the
    production-mesh dry-run; executed by distributed_yinyang).

    opt_sq (default True, §Perf optimization): run the masked
    min/argmin pass on SQUARED distances (monotone, so results are
    identical) and sqrt only the (N,) / (N,G) reduced outputs —
    removes a full (N, K) sqrt pass and its HBM round-trip per
    iteration.

    unroll_iters>0: replace the while_loop with exactly that many python
    iterations — analysis artifacts only (XLA cost_analysis does not
    descend into while bodies; the N-vs-(N-1) unrolled diff gives the
    exact per-iteration cost)."""
    axes = tuple(axes)
    pspec = P(axes, None)

    def reduce_sums(sums, counts):
        return (_psum_maybe_compressed(sums, axes, compress),
                jax.lax.psum(counts, axes))

    @functools.partial(
        _shard_map, mesh=mesh,
        in_specs=(pspec, P(None, None)),
        out_specs=(P(None, None), P(axes), P(), P(), P()),
        **_SHARD_MAP_KW,
    )
    def fit_sharded(local_points, init_c):
        groups = group_centroids(init_c, n_groups)

        # shard-local ||x||^2, computed ONCE per fit and closed over by
        # the loop body; ||c||^2 flows move -> candidate pass per
        # iteration (both passes run in the same body here)
        x2 = row_norms_sq(local_points)

        # replicated init assignment pass (local points only)
        state0 = _init_filter_state(local_points, init_c, groups, n_groups,
                                    x2=x2)

        def cond(state):
            return jnp.logical_and(state.iteration < max_iters,
                                   state.shift > tol)

        def body(state: FilterState):
            new_c, c2, ub_t, lb_dec, need, shift, tightened = \
                move_and_bounds(
                    local_points, state.centroids, state.assignments,
                    state.ub, state.lb, groups, k=k, n_groups=n_groups,
                    reduce_sums=reduce_sums, x2=x2)
            new_assign, new_ub, new_lb, pairs = dense_candidate_pass(
                local_points, new_c, state.assignments, ub_t, lb_dec,
                groups, need, n_groups=n_groups, opt_sq=opt_sq, x2=x2,
                c2=c2)
            return FilterState(state.iteration + 1, new_c, new_assign,
                               new_ub, new_lb, shift,
                               state.distance_evals.add(tightened)
                               .add(pairs))

        if unroll_iters > 0:
            state = state0
            for _ in range(unroll_iters):
                state = body(state)
        else:
            state = jax.lax.while_loop(cond, body, state0)
        d = rowwise_dists(local_points, state.centroids[state.assignments])
        inertia = jax.lax.psum(jnp.sum(d * d), axes)
        evals = jax.lax.psum(state.distance_evals.total(), axes)
        return (state.centroids, state.assignments, state.iteration,
                evals, inertia)

    return fit_sharded


def distributed_yinyang(points, init_centroids, mesh: Mesh,
                        axes: Sequence[str] = ("data",),
                        n_groups: int | None = None,
                        max_iters: int = 100, tol: float = 1e-4,
                        compress: bool = False) -> KMeansResult:
    """Run filtered K-means with points sharded over ``axes`` of ``mesh``.

    ``points`` may be a host array (it is sharded on entry) or already a
    sharded jax.Array with the right layout.
    """
    k = init_centroids.shape[0]
    if n_groups is None:
        n_groups = max(k // 10, 1)
    n_groups = int(min(n_groups, k))
    axes = tuple(axes)
    fit_sharded = make_fit_sharded(mesh, axes, k, n_groups, max_iters,
                                   tol, compress)
    points = jax.device_put(points, NamedSharding(mesh, P(axes, None)))
    init_c = jax.device_put(init_centroids.astype(jnp.float32),
                            NamedSharding(mesh, P()))
    c, a, i, evals, inertia = jax.jit(fit_sharded)(points, init_c)
    return KMeansResult(c, a, i, evals, inertia)
