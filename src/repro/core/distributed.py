"""Distributed KPynq: data-parallel filtered K-means under shard_map.

Points are sharded along one (or a flattened set of) mesh axes; bounds
(ub/lb) and assignments live with their shard; centroids are replicated.
Each iteration the only communication is a psum of the (K, D) partial
sums + (K,) counts — exactly the FPGA design's "stream points through,
accumulate centroids centrally" pattern mapped onto ICI collectives
(and the simplified map-reduce framing of Li et al.: map = per-shard
assignment, reduce = the centroid psum). Filtering is per-shard local,
so the work saving composes with parallelism.

Two per-shard realisations of the candidate pass:

``backend="compact"`` (default, :func:`make_fit_sharded_engine`)
    The engine's capacity-bucketed two-level compaction, run INSIDE the
    ``shard_map`` body: each shard carries its own bucket level through
    the ``lax.while_loop`` and switches levels shard-locally over a
    static capacity ladder (``engine.cap_ladders`` /
    ``engine.ladder_candidate_pass``) with the tuned downshift
    hysteresis — no host syncs anywhere in the sharded loop. The
    convergence test rides on the psum'd centroid sums (every shard
    sees the same drift, so the while conds agree), and the
    ``EvalCount`` work counter is psum'd at the end.
``backend="dense"`` (:func:`make_fit_sharded`)
    The legacy masked-dense pass over every shard point (exact, no
    skipped FLOPs) — the oracle the compact path is tested against,
    and the AOT-lowering target of the production-mesh dry-run.

The per-shard iteration is built from the ENGINE's pieces
(``engine.move_and_bounds`` with a psum reduction hook +
``engine.ladder_candidate_pass`` / ``engine.dense_candidate_pass``) —
one implementation of the filter math shared by the local and
distributed paths, so exactness fixes land in both at once.

Optional int8 compression of the psum payload (``compress=True``)
applies to the (K, D) partial-sums tensor only (counts and scalars stay
exact) — the gradient-compression analogue for the centroid sums.

Uneven shard sizes are handled by padding to the shard lattice with
sentinel rows (``assignment = K``, ``ub = 0``, ``lb = +inf``): the
sentinel drops out of every ``segment_sum`` and the zero/inf bounds
keep padded rows filtered forever, so they cost no candidate work and
touch no statistics.

:func:`make_stream_bounds_sharded` / :func:`make_stream_update_sharded`
are the sharded analogues of ``engine.stream_bounds`` /
``engine.stream_update`` — one global mini-batch split over the mesh,
candidate pass per shard, psum'd batch sums/counts feeding the decayed
EMA — driven by ``repro.streaming.StreamingKMeans(mesh=...)``.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# shard_map moved out of jax.experimental (and check_rep was renamed
# check_vma) across jax generations; support both so `import repro.core`
# works everywhere. The flag disables the replication/vma check: psum
# outputs are value-replicated but the static analysis cannot prove it
# through the while_loop carry.
try:
    from jax.experimental.shard_map import shard_map as _shard_map
    _SHARD_MAP_KW = {"check_rep": False}
except ImportError:                      # jax >= 0.7
    _shard_map = jax.shard_map
    _SHARD_MAP_KW = {"check_vma": False}

from .distances import row_norms_sq, rowwise_dists
from .engine import (DEFAULT_CONFIG, EngineCarry, EngineConfig,
                     StreamStepOut, build_group_tables, cap_ladders,
                     compact_candidate_pass, dense_candidate_pass,
                     ladder_candidate_pass, move_and_bounds, select_bucket,
                     stream_bounds, stream_ema_and_decay, _init_carry)
from .kmeans import (FilterState, KMeansResult, _init_filter_state,
                     centroid_sums, group_centroids)


def _psum_maybe_compressed(x: jnp.ndarray, axes, compress: bool):
    if not compress:
        return jax.lax.psum(x, axes)
    # Error-feedback-free single-shot int8: scale by per-tensor absmax.
    # Exact enough for centroid sums (relative error ~1/127) and the
    # error is self-correcting across Lloyd iterations; tests check
    # convergence to the same inertia ballpark.
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return jax.lax.psum(deq, axes)


def make_fit_sharded(mesh: Mesh, axes, k: int, n_groups: int,
                     max_iters: int, tol: float, compress: bool = False,
                     opt_sq: bool = True, unroll_iters: int = 0):
    """Build the jittable shard_map K-means fit (AOT-lowerable for the
    production-mesh dry-run; executed by distributed_yinyang).

    opt_sq (default True, §Perf optimization): run the masked
    min/argmin pass on SQUARED distances (monotone, so results are
    identical) and sqrt only the (N,) / (N,G) reduced outputs —
    removes a full (N, K) sqrt pass and its HBM round-trip per
    iteration.

    unroll_iters>0: replace the while_loop with exactly that many python
    iterations — analysis artifacts only (XLA cost_analysis does not
    descend into while bodies; the N-vs-(N-1) unrolled diff gives the
    exact per-iteration cost)."""
    axes = tuple(axes)
    pspec = P(axes, None)

    def reduce_sums(sums, counts):
        return (_psum_maybe_compressed(sums, axes, compress),
                jax.lax.psum(counts, axes))

    @functools.partial(
        _shard_map, mesh=mesh,
        in_specs=(pspec, P(None, None)),
        out_specs=(P(None, None), P(axes), P(), P(), P()),
        **_SHARD_MAP_KW,
    )
    def fit_sharded(local_points, init_c):
        groups = group_centroids(init_c, n_groups)

        # shard-local ||x||^2, computed ONCE per fit and closed over by
        # the loop body; ||c||^2 flows move -> candidate pass per
        # iteration (both passes run in the same body here)
        x2 = row_norms_sq(local_points)

        # replicated init assignment pass (local points only)
        state0 = _init_filter_state(local_points, init_c, groups, n_groups,
                                    x2=x2)

        def cond(state):
            return jnp.logical_and(state.iteration < max_iters,
                                   state.shift > tol)

        def body(state: FilterState):
            new_c, c2, ub_t, lb_dec, need, shift, tightened = \
                move_and_bounds(
                    local_points, state.centroids, state.assignments,
                    state.ub, state.lb, groups, k=k, n_groups=n_groups,
                    reduce_sums=reduce_sums, x2=x2)
            new_assign, new_ub, new_lb, pairs = dense_candidate_pass(
                local_points, new_c, state.assignments, ub_t, lb_dec,
                groups, need, n_groups=n_groups, opt_sq=opt_sq, x2=x2,
                c2=c2)
            return FilterState(state.iteration + 1, new_c, new_assign,
                               new_ub, new_lb, shift,
                               state.distance_evals.add(tightened)
                               .add(pairs))

        if unroll_iters > 0:
            state = state0
            for _ in range(unroll_iters):
                state = body(state)
        else:
            state = jax.lax.while_loop(cond, body, state0)
        d = rowwise_dists(local_points, state.centroids[state.assignments])
        inertia = jax.lax.psum(jnp.sum(d * d), axes)
        evals = jax.lax.psum(state.distance_evals.total(), axes)
        return (state.centroids, state.assignments, state.iteration,
                evals, inertia)

    return fit_sharded


def make_fit_sharded_engine(mesh: Mesh, axes, k: int, n_groups: int,
                            max_iters: int, tol: float, *, shard_n: int,
                            compress: bool = False,
                            config: EngineConfig | None = None,
                            max_branches: int = 12):
    """Build the compact (capacity-bucketed) sharded fit.

    Returns a shard_map'd ``fit(local_points, valid, init_c, groups,
    members, gsize) -> (centroids, assignments, n_iters, evals,
    inertia)`` where ``valid`` masks sentinel padding rows (see module
    docstring), ``groups`` is the (K,) centroid->group map and
    ``members``/``gsize`` the host-built group tables
    (``engine.build_group_tables`` — built OUTSIDE the sharded program,
    so the per-point group buckets use the true ``Lmax``, not the K
    upper bound).

    The body is the engine's split-loop construction (pending candidate
    pass at the top of each iteration, one epilogue pass after the
    loop) with the bucket machinery fully in-trace: each shard carries
    ``(level_n, level_g)`` through the while_loop, runs
    ``ladder_candidate_pass`` at its level, and transitions via
    ``select_bucket`` using its OWN candidate count / group high-water
    — per-shard work-proportional capacities with zero host round
    trips. ``cfg.min_cap`` floors the ladder; ``cfg.down_n``/``down_g``
    set the downshift hysteresis; ``cfg.chunk`` and
    ``cfg.group_gather_factor`` pick each branch's gather-vs-GEMM
    crossover; ``cfg.refresh_in_pass`` places the own-distance refresh
    (full-shard rowwise vs on the compacted survivor buffer).
    """
    axes = tuple(axes)
    cfg = config or DEFAULT_CONFIG
    cap_ns, cap_gs = cap_ladders(shard_n, n_groups, min_cap=cfg.min_cap,
                                 max_branches=max_branches)
    pspec = P(axes, None)

    def reduce_sums(sums, counts):
        return (_psum_maybe_compressed(sums, axes, compress),
                jax.lax.psum(counts, axes))

    refresh = not cfg.refresh_in_pass

    @functools.partial(
        _shard_map, mesh=mesh,
        in_specs=(pspec, P(axes), P(None, None), P(None), P(None, None),
                  P(None)),
        out_specs=(P(None, None), P(axes), P(), P(), P()),
        **_SHARD_MAP_KW,
    )
    def fit_sharded(local_points, valid, init_c, groups, members, gsize):
        carry0 = _init_carry(local_points, init_c, groups,
                             n_groups=n_groups)
        # sentinel-mask the padding rows: assignment K drops out of
        # every segment_sum; ub=0 / lb=inf keeps them filtered forever.
        # Their K initial distance rows never ran semantically — take
        # them back out of the eval count.
        pad = jnp.sum(1.0 - valid.astype(jnp.float32))
        carry0 = carry0._replace(
            assignments=jnp.where(valid, carry0.assignments, k),
            ub=jnp.where(valid, carry0.ub, 0.0),
            lb=jnp.where(valid[:, None], carry0.lb, jnp.inf),
            evals=carry0.evals.add(-pad * k))

        def candidate(carry, ln, lg):
            return ladder_candidate_pass(
                local_points, carry.centroids, carry.assignments,
                carry.ub, carry.lb, groups, members, gsize, carry.need,
                ln, lg, cap_ns=cap_ns, cap_gs=cap_gs, n_groups=n_groups,
                chunk=cfg.chunk,
                group_gather_factor=cfg.group_gather_factor,
                x2=carry.x2, c2=carry.c2,
                refresh_ub=cfg.refresh_in_pass)

        def cond(state):
            carry, _, _ = state
            # the centroid sums are psum'd, so shift is replicated:
            # every shard's cond agrees and the collectives stay in
            # lockstep even when shards sit in different buckets
            return jnp.logical_and(carry.iteration < max_iters,
                                   carry.shift > tol)

        def body(state):
            carry, ln, lg = state
            new_as, new_ub, new_lb, pairs, gmax = candidate(carry, ln, lg)
            new_c, new_c2, ub_t, lb_dec, need, shift, tightened = \
                move_and_bounds(local_points, carry.centroids, new_as,
                                new_ub, new_lb, groups, k=k,
                                n_groups=n_groups,
                                reduce_sums=reduce_sums, x2=carry.x2,
                                refresh=refresh)
            n_cand = jnp.sum(need.astype(jnp.int32))
            carry = EngineCarry(carry.iteration + 1, new_c, new_c2,
                                new_as, ub_t, lb_dec, carry.x2, need,
                                n_cand, gmax, shift,
                                carry.evals.add(pairs).add(tightened))
            ln, lg = select_bucket(n_cand, gmax, ln, lg, cap_ns=cap_ns,
                                   cap_gs=cap_gs, down_n=cfg.down_n,
                                   down_g=cfg.down_g)
            return carry, ln, lg

        state0 = (carry0, jnp.int32(0), jnp.int32(0))
        carry, ln, lg = jax.lax.while_loop(cond, body, state0)

        # epilogue: the final pending candidate pass + masked inertia
        new_as, _, _, pairs, _ = candidate(carry, ln, lg)
        evals = carry.evals.add(pairs)
        own = carry.centroids[jnp.minimum(new_as, k - 1)]
        d = rowwise_dists(local_points, own)
        inertia = jax.lax.psum(
            jnp.sum(jnp.where(valid, d * d, 0.0)), axes)
        total = jax.lax.psum(evals.total(), axes)
        return (carry.centroids, new_as, carry.iteration, total, inertia)

    return fit_sharded


def _mesh_shards(mesh: Mesh, axes) -> int:
    return int(np.prod([mesh.shape[a] for a in axes], dtype=np.int64))


# Builder memos: a fresh shard_map closure is a fresh jit cache key, so
# without these every distributed_yinyang call would re-trace AND
# re-compile the whole sharded program (the compact ladder compiles one
# pass instance per bucket level — seconds of XLA time on CPU).
@functools.lru_cache(maxsize=64)
def _jitted_fit_dense(mesh: Mesh, axes, k, n_groups, max_iters, tol,
                      compress):
    return jax.jit(make_fit_sharded(mesh, axes, k, n_groups, max_iters,
                                    tol, compress))


@functools.lru_cache(maxsize=64)
def _jitted_fit_engine(mesh: Mesh, axes, k, n_groups, max_iters, tol,
                       shard_n, compress, config, max_branches):
    return jax.jit(make_fit_sharded_engine(
        mesh, axes, k, n_groups, max_iters, tol, shard_n=shard_n,
        compress=compress, config=config, max_branches=max_branches))


def _pad_sharded(arr_np: np.ndarray, shards: int):
    """Pad (N, ...) to a multiple of ``shards`` rows; returns
    ``(padded, valid bool mask)``."""
    n = len(arr_np)
    n_pad = (-n) % shards
    valid = np.arange(n + n_pad) < n
    if n_pad:
        pad = np.zeros((n_pad,) + arr_np.shape[1:], arr_np.dtype)
        arr_np = np.concatenate([arr_np, pad], axis=0)
    return arr_np, valid


def _sharded_config(shard_n: int, k: int, d: int, shards: int,
                    config: EngineConfig | None,
                    tune: str) -> EngineConfig:
    """Resolve the per-shard engine configuration: explicit ``config``
    wins; otherwise consult the tuning cache under the shard-count
    signature (``repro.tune.signature(..., shards=)``), falling back to
    the single-device signature of the per-shard problem, then to the
    defaults. The tuned ``backend`` field is ignored here — the sharded
    body realises its own pass; ``"force"`` degrades to ``"auto"`` (the
    built-in measured search times single-device fits — tune the
    sharded key explicitly with ``repro.tune.autotune(shards=...)`` and
    a sharded measure hook)."""
    if config is not None:
        return config
    if tune == "off":
        return DEFAULT_CONFIG
    from .. import tune as _tune
    cfg = _tune.lookup(n=shard_n, k=k, d=d, shards=shards)
    if cfg is None:
        cfg = _tune.lookup(n=shard_n, k=k, d=d)
    return cfg or DEFAULT_CONFIG


def distributed_yinyang(points, init_centroids, mesh: Mesh,
                        axes: Sequence[str] = ("data",),
                        n_groups: int | None = None,
                        max_iters: int = 100, tol: float = 1e-4,
                        compress: bool = False, backend: str = "compact",
                        config: EngineConfig | None = None,
                        tune: str = "auto",
                        max_branches: int = 12) -> KMeansResult:
    """Run filtered K-means with points sharded over ``axes`` of ``mesh``.

    ``backend="compact"`` (default) runs the engine's two-level
    capacity-bucketed compaction per shard (see
    :func:`make_fit_sharded_engine`); ``"dense"`` keeps the legacy
    masked-dense per-shard pass (exact oracle; requires N divisible by
    the shard count). ``tune`` consults the per-(platform, N, K, D,
    shards) tuning cache for the compact body's capacities/crossovers;
    ``config`` pins them explicitly.

    ``points`` may be a host array (it is sharded — and, on the compact
    path, padded to the shard lattice — on entry) or an already-sharded
    jax.Array with the right layout.
    """
    if backend not in ("compact", "dense"):
        raise ValueError(f"unknown distributed backend {backend!r}; "
                         f"expected 'compact' or 'dense'")
    if tune not in ("auto", "off", "force"):
        raise ValueError(f"unknown tune mode {tune!r}; expected "
                         f"'auto', 'off' or 'force'")
    k = init_centroids.shape[0]
    if n_groups is None:
        n_groups = max(k // 10, 1)
    n_groups = int(min(n_groups, k))
    axes = tuple(axes)
    shards = _mesh_shards(mesh, axes)
    init_c = jnp.asarray(init_centroids, jnp.float32)

    if backend == "dense":
        n = points.shape[0]
        if n % shards:
            raise ValueError(
                f"backend='dense' needs N ({n}) divisible by the shard "
                f"count ({shards}); use backend='compact' for uneven "
                f"shards")
        fit_sharded = _jitted_fit_dense(mesh, axes, k, n_groups,
                                        int(max_iters), float(tol),
                                        bool(compress))
        points = jax.device_put(points, NamedSharding(mesh, P(axes, None)))
        init_d = jax.device_put(init_c, NamedSharding(mesh, P()))
        c, a, i, evals, inertia = fit_sharded(points, init_d)
        return KMeansResult(c, a, i, evals, inertia)

    n, d = points.shape
    if n % shards:
        # uneven: materialise on host once to append the sentinel rows
        pts_in, valid_np = _pad_sharded(
            np.asarray(jax.device_get(points), np.float32), shards)
    else:
        # no padding needed: device-resident arrays stay on device
        # (jnp.asarray is a no-op for committed f32 arrays)
        pts_in = jnp.asarray(points, jnp.float32)
        valid_np = np.ones((n,), bool)
    shard_n = len(pts_in) // shards
    cfg = _sharded_config(shard_n, k, d, shards, config, tune)

    # group map + tables, built once on the host (true Lmax)
    groups = group_centroids(init_c, n_groups)
    groups_np = np.asarray(jax.device_get(groups))
    members, gsize = build_group_tables(groups_np, n_groups)

    fit_sharded = _jitted_fit_engine(
        mesh, axes, k, n_groups, int(max_iters), float(tol), shard_n,
        bool(compress), cfg, int(max_branches))
    shard = NamedSharding(mesh, P(axes, None))
    repl = NamedSharding(mesh, P())
    args = (jax.device_put(pts_in, shard),
            jax.device_put(valid_np, NamedSharding(mesh, P(axes))),
            jax.device_put(init_c, repl),
            jax.device_put(groups, repl),
            jax.device_put(members, repl),
            jax.device_put(gsize, repl))
    c, a, i, evals, inertia = fit_sharded(*args)
    return KMeansResult(c, a[:n], i, evals, inertia)


# --------------------------------------------------------------------------
# sharded streaming steps (driven by repro.streaming.StreamingKMeans)
# --------------------------------------------------------------------------

def make_stream_bounds_sharded(mesh: Mesh, axes: Sequence[str] = ("data",)):
    """Sharded analogue of ``engine.stream_bounds``: the point-level
    filter over carried (drift-inflated) bounds, per shard of one
    global mini-batch. Returns a jitted ``(points, centroids, assign,
    ub, lb) -> (ub_t, need, max_shard_cand, tightened)`` where
    ``max_shard_cand`` is the pmax'd PER-SHARD candidate count — the
    number the caller's static ``cap_n`` must cover."""
    axes = tuple(axes)

    @functools.partial(
        _shard_map, mesh=mesh,
        in_specs=(P(axes, None), P(None, None), P(axes), P(axes),
                  P(axes, None)),
        out_specs=(P(axes), P(axes), P(), P()),
        **_SHARD_MAP_KW,
    )
    def bounds(points, centroids, assign, ub, lb):
        ub_t, need, n_cand, n_tight = stream_bounds(points, centroids,
                                                    assign, ub, lb)
        return (ub_t, need, jax.lax.pmax(n_cand, axes),
                jax.lax.psum(n_tight, axes))

    return jax.jit(bounds)


def make_stream_update_sharded(mesh: Mesh, axes, *, k: int, n_groups: int,
                               cap_n: int, cap_g: int, chunk: int = 2048,
                               group_gather_factor: int = 4,
                               compress: bool = False):
    """Sharded analogue of ``engine.stream_update``: one global
    mini-batch split over the mesh, the engine's compacted candidate
    pass per shard (``cap_n`` must cover the max PER-SHARD candidate
    count — the caller syncs it via :func:`make_stream_bounds_sharded`),
    then the psum'd batch sums/counts feed the decayed count-weighted
    centroid EMA, computed replicated so every shard agrees. Returns a
    jitted function with the same :class:`~repro.core.engine.
    StreamStepOut` result; ``assignments``/``ub``/``lb`` come back
    sharded along ``axes`` (gathered to the global batch on read).
    ``compress=True`` int8-compresses the (K, D) partial-sums psum
    payload only."""
    axes = tuple(axes)

    @functools.partial(
        _shard_map, mesh=mesh,
        in_specs=(P(axes, None), P(None, None), P(None), P(), P(None),
                  P(None, None), P(None), P(axes), P(axes), P(axes, None),
                  P(axes)),
        out_specs=StreamStepOut(
            P(None, None), P(None), P(axes), P(axes), P(axes, None),
            P(), P(), P(None), P(None), P(None), P()),
        **_SHARD_MAP_KW,
    )
    def update(points, centroids, counts, decay, groups, members, gsize,
               assignments, ub_t, lb, need):
        x2 = row_norms_sq(points)
        c2 = row_norms_sq(centroids)
        new_as, nub, nlb, pairs, gmax = compact_candidate_pass(
            points, centroids, assignments, ub_t, lb, groups, members,
            gsize, need, cap_n=cap_n, cap_g=cap_g, n_groups=n_groups,
            chunk=chunk, opt_sq=True, x2=x2, c2=c2,
            group_gather_factor=group_gather_factor)
        bsums, bcounts = centroid_sums(points, new_as, k)
        bsums = _psum_maybe_compressed(bsums, axes, compress)
        bcounts = jax.lax.psum(bcounts, axes)
        # the reduced sums/counts make the EMA (and drift) replicated;
        # only the per-shard scalars still need reducing afterwards
        out = stream_ema_and_decay(
            centroids, counts, decay, bsums, bcounts, new_as, nub, nlb,
            jax.lax.psum(pairs, axes), jax.lax.pmax(gmax, axes), groups,
            n_groups=n_groups)
        return out._replace(
            batch_cost=jax.lax.psum(out.batch_cost, axes))

    return jax.jit(update)
