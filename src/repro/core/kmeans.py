"""Lloyd and triangle-inequality-filtered K-means (the KPynq algorithm).

Two exact algorithms with identical fixed points:

* ``lloyd``      — the standard baseline the paper compares against
                   (N*K distance evaluations per iteration).
* ``yinyang``    — KPynq's multi-level filter. ``n_groups == 1`` is the
                   paper's *point-level* filter alone (Hamerly-style
                   global bound); ``n_groups > 1`` adds the
                   *group-level* filter (Yinyang-style per-group lower
                   bounds).

Both are pure JAX (`lax.while_loop`), run anywhere, and report a
``distance_evals`` counter — the paper's work-efficiency metric. The
actual FLOP saving on TPU is realised by the Pallas block-skip /
compaction kernels in ``repro.kernels``; this module is the algorithmic
ground truth they are tested against.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .distances import (pairwise_dists, pairwise_sq_dists, row_norms_sq,
                        rowwise_dists)


# --------------------------------------------------------------------------
# shared pieces
# --------------------------------------------------------------------------

def centroid_sums(points, assignments, k, weights=None):
    """Per-cluster partial sums + counts — the psum'able half of the
    centroid update (the distributed fit reduces these across shards
    before dividing).

    ``weights``: optional (N,) per-point sample weights — the sums
    become weighted sums and the counts the per-cluster weighted mass.
    ``None`` keeps the exact pre-weight program (and uniform weights
    of 1.0 are bit-identical to it: multiplying by 1.0f is exact)."""
    pts = points.astype(jnp.float32)
    if weights is None:
        sums = jax.ops.segment_sum(pts, assignments,
                                   num_segments=k)                 # (K, D)
        counts = jax.ops.segment_sum(
            jnp.ones((pts.shape[0],), jnp.float32), assignments,
            num_segments=k)                                        # (K,)
    else:
        w = weights.astype(jnp.float32)
        sums = jax.ops.segment_sum(w[:, None] * pts, assignments,
                                   num_segments=k)
        counts = jax.ops.segment_sum(w, assignments, num_segments=k)
    return sums, counts


def centroids_from_sums(sums, counts, prev_centroids):
    """Divide reduced sums by counts. Empty clusters keep their previous
    centroid (standard practice; also what keeps the filtered and
    unfiltered paths bit-identical). THE single copy of that rule."""
    safe = jnp.maximum(counts, 1.0)[:, None]
    return jnp.where(counts[:, None] > 0, sums / safe, prev_centroids)


def update_centroids(points, assignments, k, prev_centroids,
                     weights=None):
    """Segment-sum centroid update — O(N*D), the right formulation for
    CPU/scatter hardware. (The TPU path uses the one-hot MXU matmul in
    kernels/centroid_update.py instead; same math.)
    """
    sums, counts = centroid_sums(points, assignments, k, weights=weights)
    return centroids_from_sums(sums, counts, prev_centroids), counts


@functools.partial(jax.jit, static_argnames=("n_groups", "n_iters"))
def group_centroids(centroids: jnp.ndarray, n_groups: int, n_iters: int = 5):
    """Partition centroids into groups by clustering the centroids
    themselves (the Yinyang construction). Deterministic: seeds with a
    strided subset. Returns int32 group ids of shape (K,).

    Jitted (it is called eagerly by every fit driver, and an un-jitted
    ``fori_loop`` costs ~100ms of per-op dispatch even for tiny K)."""
    k = centroids.shape[0]
    if n_groups >= k:
        return jnp.arange(k, dtype=jnp.int32) % n_groups
    stride = max(k // n_groups, 1)
    seeds = centroids[::stride][:n_groups]

    def body(_, seeds):
        d = pairwise_dists(centroids, seeds)
        gid = jnp.argmin(d, axis=1)
        new_seeds, _ = update_centroids(centroids, gid, n_groups, seeds)
        return new_seeds

    seeds = jax.lax.fori_loop(0, n_iters, body, seeds)
    return jnp.argmin(pairwise_dists(centroids, seeds), axis=1).astype(jnp.int32)


class EvalCount(NamedTuple):
    """Precision-safe distance-evaluation counter.

    A single fp32 accumulator silently drops increments once the running
    total passes 2^24 (adding ``n*k`` per iteration at paper scale blows
    through that in one or two iterations). JAX runs with x64 disabled,
    so int64/float64 are unavailable on-device; instead we carry a
    compensated (hi, lo) fp32 pair (Fast2Sum): every rounding error of
    ``hi`` is captured exactly in ``lo``, keeping integer counts exact to
    ~2^48. ``total()`` collapses to one fp32 scalar (single final
    rounding) so ``KMeansResult.distance_evals`` keeps its scalar API.
    """
    hi: jnp.ndarray               # running sum, fp32
    lo: jnp.ndarray               # compensation term, fp32

    @staticmethod
    def of(x) -> "EvalCount":
        return EvalCount(jnp.asarray(x, jnp.float32), jnp.float32(0))

    def add(self, x) -> "EvalCount":
        x = jnp.asarray(x, jnp.float32)
        s = self.hi + x
        # Neumaier branch: recover the exact rounding error of hi + x
        big = jnp.where(jnp.abs(self.hi) >= jnp.abs(x), self.hi, x)
        small = jnp.where(jnp.abs(self.hi) >= jnp.abs(x), x, self.hi)
        return EvalCount(s, self.lo + ((big - s) + small))

    def total(self) -> jnp.ndarray:
        return self.hi + self.lo


class KMeansResult(NamedTuple):
    centroids: jnp.ndarray        # (K, D) fp32
    assignments: jnp.ndarray      # (N,) int32
    n_iters: jnp.ndarray          # scalar int32
    distance_evals: jnp.ndarray   # scalar fp32 (EvalCount.total())
    inertia: jnp.ndarray          # sum of squared distances to assigned


def _inertia(points, centroids, assignments, weights=None):
    d = rowwise_dists(points, centroids[assignments])
    d2 = d * d
    if weights is not None:
        d2 = d2 * weights.astype(jnp.float32)
    return jnp.sum(d2)


# --------------------------------------------------------------------------
# Lloyd baseline
# --------------------------------------------------------------------------

def lloyd(points, init_centroids, max_iters: int = 100, tol: float = 1e-4,
          weights=None):
    """Standard K-means — the CPU baseline of the paper's Table.
    ``weights``: optional (N,) sample weights (weighted centroid means
    and inertia; the distance work per iteration is unchanged)."""
    k = init_centroids.shape[0]
    n = points.shape[0]

    def cond(state):
        i, _, _, shift, _ = state
        return jnp.logical_and(i < max_iters, shift > tol)

    def body(state):
        i, centroids, _, _, evals = state
        d = pairwise_dists(points, centroids)
        assign = jnp.argmin(d, axis=1).astype(jnp.int32)
        new_c, _ = update_centroids(points, assign, k, centroids,
                                    weights=weights)
        shift = jnp.max(jnp.linalg.norm(new_c - centroids, axis=-1))
        return i + 1, new_c, assign, shift, evals.add(jnp.float32(n) * k)

    init = (jnp.int32(0), init_centroids.astype(jnp.float32),
            jnp.zeros(n, jnp.int32), jnp.float32(jnp.inf), EvalCount.of(0))
    i, centroids, assign, _, evals = jax.lax.while_loop(cond, body, init)
    return KMeansResult(centroids, assign, i, evals.total(),
                        _inertia(points, centroids, assign, weights))


# --------------------------------------------------------------------------
# KPynq multi-level filtered K-means (Yinyang/Hamerly family)
# --------------------------------------------------------------------------

class FilterState(NamedTuple):
    iteration: jnp.ndarray    # int32
    centroids: jnp.ndarray    # (K, D)
    assignments: jnp.ndarray  # (N,)
    ub: jnp.ndarray           # (N,)   upper bound on d(x, a(x))
    lb: jnp.ndarray           # (N, G) lower bound on d(x, nearest in group)
    shift: jnp.ndarray        # max centroid drift last iter
    distance_evals: EvalCount


@functools.partial(jax.jit, static_argnums=(3,))
def _init_filter_state(points, centroids, groups, n_groups, x2=None,
                       c2=None):
    """Initial exact assignment + bounds. ``x2``/``c2``: optional cached
    squared norms (the engine computes ``||x||^2`` once per fit and
    threads it through; passing them here keeps that single copy).
    Reductions run on SQUARED distances; only the (N,) / (N, G)
    outputs are sqrt'ed (monotone => identical bounds, one fewer
    (N, K) sqrt pass)."""
    n, k = points.shape[0], centroids.shape[0]
    d2 = pairwise_sq_dists(points, centroids, x2, c2)           # (N, K)
    assign = jnp.argmin(d2, axis=1).astype(jnp.int32)
    ub = jnp.sqrt(jnp.min(d2, axis=1))
    # lb[x, g] = min over centroids in g, excluding the assigned one.
    d2_excl = d2.at[jnp.arange(n), assign].set(jnp.inf)
    lb = jnp.sqrt(jax.ops.segment_min(d2_excl.T, groups,
                                      num_segments=n_groups).T)  # (N, G)
    return FilterState(jnp.int32(0), centroids.astype(jnp.float32), assign,
                       ub, lb, jnp.float32(jnp.inf),
                       EvalCount.of(jnp.float32(n) * k))


def _filtered_step(points, state: FilterState, groups, n_groups: int, k: int,
                   x2=None, weights=None):
    """One KPynq iteration: centroid move -> bound maintenance ->
    point-level filter -> group-level filter -> masked distance pass.

    ``x2``: cached ``||x||^2`` (``yinyang`` computes it once per fit);
    the new centroids' ``||c||^2`` is computed once here and shared by
    the own-distance refresh and the masked pass. Reductions run on
    SQUARED distances (monotone, so results are identical) and sqrt
    only the reduced outputs."""
    n = points.shape[0]
    rows = jnp.arange(n)

    # 1. move centroids from current assignments; measure drift
    new_c, _ = update_centroids(points, state.assignments, k,
                                state.centroids, weights=weights)
    c2 = row_norms_sq(new_c)                       # once per iteration
    drift = jnp.linalg.norm(new_c - state.centroids, axis=-1)          # (K,)
    group_drift = jax.ops.segment_max(drift, groups, num_segments=n_groups)
    shift = jnp.max(drift)

    # 2. bound maintenance (triangle inequality)
    ub = state.ub + drift[state.assignments]
    lb = jnp.maximum(state.lb - group_drift[None, :], 0.0)
    glb = jnp.min(lb, axis=1)                                          # (N,)

    # 3. POINT-LEVEL FILTER: ub < min_g lb[g]  =>  zero distance work
    maybe = ub > glb
    # tighten ub with one exact distance for surviving points
    if x2 is None:
        d_own = rowwise_dists(points, new_c[state.assignments])
    else:
        own = new_c[state.assignments]
        d_own = jnp.sqrt(jnp.maximum(
            x2 - 2.0 * jnp.sum(points.astype(jnp.float32) * own, axis=-1)
            + c2[state.assignments], 0.0))
    ub_t = jnp.where(maybe, d_own, ub)
    need = ub_t > glb
    evals = state.distance_evals.add(jnp.sum(maybe.astype(jnp.float32)))

    # 4. GROUP-LEVEL FILTER: only groups with lb[x,g] < ub survive
    group_need = need[:, None] & (lb < ub_t[:, None])                  # (N, G)
    cand = group_need[:, groups]                                       # (N, K)
    evals = evals.add(jnp.sum(cand.astype(jnp.float32)))

    # 5. masked distance pass (the Distance Calculator). Algorithmically
    #    only `cand` entries are needed; the Pallas kernel skips
    #    non-candidate blocks — here we mask for exact semantics.
    d2_all = pairwise_sq_dists(points, new_c, x2, c2)
    d2_cand = jnp.where(cand, d2_all, jnp.inf)
    best_other = jnp.argmin(d2_cand, axis=1).astype(jnp.int32)
    best_other_d = jnp.sqrt(jnp.min(d2_cand, axis=1))
    new_assign = jnp.where(best_other_d < ub_t, best_other, state.assignments)
    new_ub = jnp.minimum(ub_t, best_other_d)

    # 6. refresh lb for computed groups: min distance in group excluding
    #    the (new) assigned centroid; untouched groups keep decayed lb.
    d2_excl = d2_cand.at[rows, new_assign].set(jnp.inf)
    lb_comp = jnp.sqrt(jax.ops.segment_min(d2_excl.T, groups,
                                           num_segments=n_groups).T)   # (N, G)
    new_lb = jnp.where(group_need, lb_comp, lb)
    # Exactness fix (Yinyang): when x is reassigned away from its old
    # centroid b, b re-enters the "non-assigned" pool of its group, at
    # exact distance d(x, b) = ub_t. A skipped old group's decayed lb can
    # exceed that, so cap it. (For computed groups lb_comp already
    # accounts for b; min() is a no-op there.)
    changed = best_other_d < ub_t
    old_group = groups[state.assignments]
    new_lb = new_lb.at[rows, old_group].min(jnp.where(changed, ub_t, jnp.inf))

    return FilterState(state.iteration + 1, new_c, new_assign, new_ub,
                       new_lb, shift, evals)


def yinyang(points, init_centroids, n_groups: int | None = None,
            max_iters: int = 100, tol: float = 1e-4, weights=None):
    """KPynq filtered K-means. ``n_groups=1`` -> point-level filter only;
    default ``K // 10`` groups (the Yinyang heuristic). ``weights``:
    optional (N,) sample weights — they enter the centroid means and
    the inertia only; the filters stay weight-independent."""
    k = init_centroids.shape[0]
    if n_groups is None:
        n_groups = max(k // 10, 1)
    n_groups = int(min(n_groups, k))
    groups = group_centroids(init_centroids.astype(jnp.float32), n_groups)
    x2 = row_norms_sq(points)                    # ONCE per fit
    state0 = _init_filter_state(points, init_centroids.astype(jnp.float32),
                                groups, n_groups, x2=x2)

    def cond(state):
        return jnp.logical_and(state.iteration < max_iters, state.shift > tol)

    def body(state):
        return _filtered_step(points, state, groups, n_groups, k, x2=x2,
                              weights=weights)

    state = jax.lax.while_loop(cond, body, state0)
    return KMeansResult(state.centroids, state.assignments, state.iteration,
                        state.distance_evals.total(),
                        _inertia(points, state.centroids, state.assignments,
                                 weights))
