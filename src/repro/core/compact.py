"""Legacy host-driven stream-compaction K-means driver.

Superseded by :mod:`repro.core.engine` — kept as the wall-clock
BASELINE the engine is benchmarked against (``benchmarks/
kmeans_speedup.py`` reports oracle vs compact vs engine side by side).

The iteration math is the engine's own (``engine.move_and_bounds`` /
``engine.compact_candidate_pass`` with the centroid-level bucket
disabled); what makes this the *legacy* driver is the control flow:
every iteration round-trips to the host (``int(jnp.sum(need))``,
``float(shift)``) to pick the next compaction capacity, and each new
power-of-two capacity recompiles. The engine replaces exactly that —
same math under ``lax.while_loop`` with bucketed capacities — so any
wall-clock gap between the two is pure host-sync/recompile overhead
plus the engine's group-level compaction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .distances import row_norms_sq, rowwise_dists
from .engine import compact_candidate_pass, move_and_bounds
from .kmeans import KMeansResult, _init_filter_state, group_centroids


@functools.partial(jax.jit, static_argnames=("k", "n_groups"))
def _move_and_bounds(points, x2, centroids, assignments, ub, lb, groups,
                     *, k, n_groups):
    return move_and_bounds(points, centroids, assignments, ub, lb, groups,
                           k=k, n_groups=n_groups, x2=x2)


@functools.partial(jax.jit, static_argnames=("cap", "n_groups"))
def _candidate_pass(points, x2, new_c, c2, assignments, ub_t, lb, groups,
                    need, *, cap, n_groups):
    # cap_g = n_groups disables the centroid-level bucket: this driver
    # computes every candidate against all K centroids, as the seed did.
    k = new_c.shape[0]
    dummy_members = jnp.full((n_groups, 1), -1, jnp.int32)
    dummy_gsize = jnp.zeros((n_groups,), jnp.float32)
    a, u, l, _, _ = compact_candidate_pass(
        points, new_c, assignments, ub_t, lb, groups, dummy_members,
        dummy_gsize, need, cap_n=cap, cap_g=n_groups, n_groups=n_groups,
        use_groups=False, x2=x2, c2=c2)
    return a, u, l


def yinyang_compact(points, init_centroids, n_groups=None,
                    max_iters: int = 100, tol: float = 1e-4,
                    min_cap: int = 256) -> KMeansResult:
    k = init_centroids.shape[0]
    n = points.shape[0]
    if n_groups is None:
        n_groups = max(k // 10, 1)
    n_groups = int(min(n_groups, k))
    groups = group_centroids(init_centroids.astype(jnp.float32), n_groups)
    x2 = row_norms_sq(points)                 # once per fit
    state = _init_filter_state(points, init_centroids.astype(jnp.float32),
                               groups, n_groups, x2=x2)
    centroids, assignments = state.centroids, state.assignments
    ub, lb = state.ub, state.lb
    evals = float(state.distance_evals.total())

    it = 0
    for it in range(1, max_iters + 1):
        mv = _move_and_bounds(
            points, x2, centroids, assignments, ub, lb, groups,
            k=k, n_groups=n_groups)
        centroids, c2, ub, lb = mv.centroids, mv.c2, mv.ub, mv.lb
        need, shift = mv.need, mv.shift
        evals += float(mv.tightened)
        n_cand = int(jnp.sum(need))           # per-iteration host sync
        if n_cand > 0:
            cap = max(min_cap, 1 << (n_cand - 1).bit_length())
            cap = min(cap, n)
            assignments, ub, lb = _candidate_pass(
                points, x2, centroids, c2, assignments, ub, lb, groups,
                need, cap=cap, n_groups=n_groups)
            evals += float(n_cand * k)
        if float(shift) <= tol:               # per-iteration host sync
            break

    d = rowwise_dists(points, centroids[assignments])
    return KMeansResult(centroids, assignments, jnp.int32(it),
                        jnp.float32(evals), jnp.sum(d * d))
