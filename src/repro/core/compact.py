"""Stream-compaction K-means: the work-saving actually realised on
dense-SIMD hardware (and measurably on CPU wall-clock).

The masked-dense oracle in kmeans.py has identical RESULTS but computes
every distance and throws the filtered ones away — fine as ground truth,
useless for speed. This module drives the same bound logic from the
host, gathers the surviving points into a padded bucket
(power-of-two capacities so jit recompiles O(log N) times, not per
iteration) and runs the distance pass ONLY on survivors — the TPU
equivalent is the block-skip Pallas kernel; on CPU/XLA this is what
turns filter rates into wall-clock speedup (benchmarks/kmeans_speedup).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .distances import pairwise_dists, rowwise_dists
from .kmeans import (KMeansResult, _init_filter_state, group_centroids,
                     update_centroids)


@functools.partial(jax.jit, static_argnames=("k", "n_groups"))
def _move_and_bounds(points, centroids, assignments, ub, lb, groups,
                     *, k, n_groups):
    new_c, _ = update_centroids(points, assignments, k, centroids)
    drift = jnp.linalg.norm(new_c - centroids, axis=-1)
    gd = jax.ops.segment_max(drift, groups, num_segments=n_groups)
    shift = jnp.max(drift)
    ub = ub + drift[assignments]
    lb = jnp.maximum(lb - gd[None, :], 0.0)
    glb = jnp.min(lb, axis=1)
    maybe = ub > glb
    d_own = rowwise_dists(points, new_c[assignments])
    ub_t = jnp.where(maybe, d_own, ub)
    need = ub_t > glb
    return new_c, ub_t, lb, need, shift, jnp.sum(maybe.astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("cap", "n_groups"))
def _candidate_pass(points, new_c, assignments, ub_t, lb, groups, need,
                    *, cap, n_groups):
    """Gather `cap` candidates, compute their distances to ALL centroids
    (point-level compaction), apply the group filter as a mask, and
    scatter updated (assign, ub, lb) back."""
    n = points.shape[0]
    pos = jnp.cumsum(need.astype(jnp.int32)) - 1
    slot = jnp.where(need, pos, cap)
    idx = jnp.zeros((cap,), jnp.int32).at[slot].set(
        jnp.arange(n, dtype=jnp.int32), mode="drop")
    valid = jnp.arange(cap) < jnp.sum(need.astype(jnp.int32))

    cpts = points[idx]                                       # (cap, D)
    c_ub = ub_t[idx]
    c_lb = lb[idx]                                           # (cap, G)
    c_as = assignments[idx]

    d_all = pairwise_dists(cpts, new_c)                      # (cap, K)
    gmask = (c_lb < c_ub[:, None])[:, groups]                # (cap, K)
    d_cand = jnp.where(gmask, d_all, jnp.inf)
    best = jnp.argmin(d_cand, axis=1).astype(jnp.int32)
    best_d = jnp.min(d_cand, axis=1)
    changed = best_d < c_ub
    new_as = jnp.where(changed, best, c_as)
    new_ub = jnp.minimum(c_ub, best_d)

    rows = jnp.arange(cap)
    d_excl = d_cand.at[rows, new_as].set(jnp.inf)
    # per-group min via segment_min over the (transposed) centroid axis:
    # O(cap*K) instead of the O(cap*K*G) masked-min formulation
    lb_comp = jax.ops.segment_min(d_excl.T, groups,
                                  num_segments=n_groups).T   # (cap, G)
    gneed = c_lb < c_ub[:, None]
    new_lb = jnp.where(gneed, lb_comp, c_lb)
    old_group = groups[c_as]
    new_lb = new_lb.at[rows, old_group].min(
        jnp.where(changed, c_ub, jnp.inf))

    # scatter back (invalid slots write to row idx 0 harmlessly guarded)
    write = valid
    sidx = jnp.where(write, idx, n)                           # OOB drop
    assignments = assignments.at[sidx].set(new_as, mode="drop")
    ub_out = ub_t.at[sidx].set(new_ub, mode="drop")
    lb_out = lb.at[sidx].set(new_lb, mode="drop")
    return assignments, ub_out, lb_out


def yinyang_compact(points, init_centroids, n_groups=None,
                    max_iters: int = 100, tol: float = 1e-4,
                    min_cap: int = 256) -> KMeansResult:
    k = init_centroids.shape[0]
    n = points.shape[0]
    if n_groups is None:
        n_groups = max(k // 10, 1)
    n_groups = int(min(n_groups, k))
    groups = group_centroids(init_centroids.astype(jnp.float32), n_groups)
    state = _init_filter_state(points, init_centroids.astype(jnp.float32),
                               groups, n_groups)
    centroids, assignments = state.centroids, state.assignments
    ub, lb = state.ub, state.lb
    evals = float(state.distance_evals)

    it = 0
    for it in range(1, max_iters + 1):
        centroids, ub, lb, need, shift, tighten = _move_and_bounds(
            points, centroids, assignments, ub, lb, groups,
            k=k, n_groups=n_groups)
        evals += float(tighten)
        n_cand = int(jnp.sum(need))
        if n_cand > 0:
            cap = max(min_cap, 1 << (n_cand - 1).bit_length())
            cap = min(cap, n)
            assignments, ub, lb = _candidate_pass(
                points, centroids, assignments, ub, lb, groups, need,
                cap=cap, n_groups=n_groups)
            evals += float(n_cand * k)
        if float(shift) <= tol:
            break

    d = rowwise_dists(points, centroids[assignments])
    return KMeansResult(centroids, assignments, jnp.int32(it),
                        jnp.float32(evals), jnp.sum(d * d))
