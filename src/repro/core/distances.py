"""Distance primitives shared by the K-means family.

All bound arithmetic is fp32 (the filters must never prune the true
nearest centroid); the bulk matmul term may run in bf16 on TPU via the
Pallas kernel in ``repro.kernels`` — this module is the pure-jnp
reference semantics used by the algorithm layer and the oracles.

Every pairwise primitive accepts optional precomputed squared norms
(``x2`` for rows, ``c2`` for centroids).  Point norms never change
during a fit and centroid norms change once per iteration, so the
callers (engine / reference loops) compute ``||x||^2`` ONCE PER FIT and
``||c||^2`` once per iteration and thread them through — recomputing
them inside every distance call was measurable on the hot path
(ISSUE 3). Passing ``None`` recomputes locally (reference semantics,
bit-identical: the same ``sum(x*x)`` expression either way).
"""
from __future__ import annotations

import jax.numpy as jnp


def row_norms_sq(x: jnp.ndarray) -> jnp.ndarray:
    """``||x_i||^2`` per row, (N, D) -> (N,) fp32 — THE norm expression
    shared by every distance path (callers cache its output)."""
    x = x.astype(jnp.float32)
    return jnp.sum(x * x, axis=-1)


def pairwise_sq_dists(x: jnp.ndarray, c: jnp.ndarray,
                      x2: jnp.ndarray | None = None,
                      c2: jnp.ndarray | None = None) -> jnp.ndarray:
    """Squared Euclidean distances, (N, D) x (K, D) -> (N, K).

    Expanded as ||x||^2 - 2 x.c + ||c||^2 so the dominant term is a
    single (N, D) x (D, K) matmul (MXU-friendly on the target hardware).
    ``x2`` / ``c2``: optional precomputed squared norms (see module
    docstring).
    """
    x = x.astype(jnp.float32)
    c = c.astype(jnp.float32)
    if x2 is None:
        x2 = row_norms_sq(x)
    if c2 is None:
        c2 = row_norms_sq(c)
    d2 = x2[:, None] - 2.0 * (x @ c.T) + c2[None, :]
    return jnp.maximum(d2, 0.0)                           # numerical floor


def pairwise_dists(x: jnp.ndarray, c: jnp.ndarray,
                   x2: jnp.ndarray | None = None,
                   c2: jnp.ndarray | None = None) -> jnp.ndarray:
    return jnp.sqrt(pairwise_sq_dists(x, c, x2, c2))


def rowwise_dists(x: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """d(x_i, c_i) for paired rows, (N, D) x (N, D) -> (N,)."""
    diff = x.astype(jnp.float32) - c.astype(jnp.float32)
    return jnp.sqrt(jnp.maximum(jnp.sum(diff * diff, axis=-1), 0.0))
