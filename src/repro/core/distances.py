"""Distance primitives shared by the K-means family.

All bound arithmetic is fp32 (the filters must never prune the true
nearest centroid); the bulk matmul term may run in bf16 on TPU via the
Pallas kernel in ``repro.kernels`` — this module is the pure-jnp
reference semantics used by the algorithm layer and the oracles.
"""
from __future__ import annotations

import jax.numpy as jnp


def pairwise_sq_dists(x: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """Squared Euclidean distances, (N, D) x (K, D) -> (N, K).

    Expanded as ||x||^2 - 2 x.c + ||c||^2 so the dominant term is a
    single (N, D) x (D, K) matmul (MXU-friendly on the target hardware).
    """
    x = x.astype(jnp.float32)
    c = c.astype(jnp.float32)
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)          # (N, 1)
    c2 = jnp.sum(c * c, axis=-1)                          # (K,)
    d2 = x2 - 2.0 * (x @ c.T) + c2[None, :]
    return jnp.maximum(d2, 0.0)                           # numerical floor


def pairwise_dists(x: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    return jnp.sqrt(pairwise_sq_dists(x, c))


def rowwise_dists(x: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """d(x_i, c_i) for paired rows, (N, D) x (N, D) -> (N,)."""
    diff = x.astype(jnp.float32) - c.astype(jnp.float32)
    return jnp.sqrt(jnp.maximum(jnp.sum(diff * diff, axis=-1), 0.0))
