"""Integrations of KPynq K-means into the LM stack.

1. ``kmeans_router_init`` — bootstrap MoE router weights from K-means
   centroids over (embedded) token vectors: experts start as Voronoi
   owners of embedding-space regions instead of random hyperplanes.
2. ``cluster_kv_cache`` — compress a long-context KV cache by replacing
   each key/value sequence with K weighted centroids (approximate
   attention memory for the long_500k serving regime).
Both use the filtered (work-efficient) algorithm, so bootstrap cost is
a small fraction of Lloyd's.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .init import kmeans_plusplus
from .kmeans import yinyang


def kmeans_router_init(params: dict, cfg, sample_tokens: jnp.ndarray,
                       seed: int = 0) -> dict:
    """Returns params with every layer's MoE router re-initialised to
    centroid directions of the token-embedding distribution."""
    if cfg.family != "moe":
        raise ValueError("router bootstrap only applies to MoE archs")
    embeds = jnp.take(params["embed"], sample_tokens.reshape(-1), axis=0)
    embeds = embeds.astype(jnp.float32)
    init = kmeans_plusplus(jax.random.PRNGKey(seed), embeds, cfg.n_experts)
    res = yinyang(embeds, init, max_iters=25, tol=1e-4)
    centroids = res.centroids / (
        jnp.linalg.norm(res.centroids, axis=-1, keepdims=True) + 1e-6)
    router = centroids.T.astype(params["embed"].dtype)      # (D, E)
    new_router = jnp.broadcast_to(router[None], (cfg.n_layers, *router.shape))
    out = dict(params)
    layers = dict(params["layers"])
    moe = dict(layers["moe"])
    moe["router"] = new_router
    layers["moe"] = moe
    out["layers"] = layers
    return out


def cluster_kv_cache(k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                     n_clusters: int, seed: int = 0):
    """Compress (S, H, Dh) key/value tensors to (K, H, Dh) centroid pairs
    + per-centroid counts (for count-weighted attention scores).

    Keys are clustered per head with the filtered algorithm; values are
    averaged within each key-cluster (the standard KV-clustering
    approximation)."""
    s, h, dh = k_cache.shape
    ks, vs, counts = [], [], []
    for head in range(h):
        pts = k_cache[:, head].astype(jnp.float32)
        init = kmeans_plusplus(jax.random.PRNGKey(seed + head), pts,
                               n_clusters)
        res = yinyang(pts, init, max_iters=15, tol=1e-3)
        onehot = jax.nn.one_hot(res.assignments, n_clusters,
                                dtype=jnp.float32)
        cnt = onehot.sum(0)
        v_mean = (onehot.T @ v_cache[:, head].astype(jnp.float32)) / \
            jnp.maximum(cnt[:, None], 1.0)
        ks.append(res.centroids)
        vs.append(v_mean)
        counts.append(cnt)
    return (jnp.stack(ks, axis=1), jnp.stack(vs, axis=1),
            jnp.stack(counts, axis=1))


def clustered_attention_scores(q: jnp.ndarray, k_centroids: jnp.ndarray,
                               counts: jnp.ndarray, scale: float):
    """Attention over clustered keys: softmax(q.k_c * scale + log n_c) —
    each centroid stands for n_c original positions."""
    scores = jnp.einsum("hd,khd->hk", q.astype(jnp.float32),
                        k_centroids) * scale
    scores = scores + jnp.log(jnp.maximum(counts.T, 1.0))
    return jax.nn.softmax(scores, axis=-1)
