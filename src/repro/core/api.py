"""Public sklearn-flavoured API for the KPynq K-means family."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import kmeans as _km
from .init import kmeans_plusplus, random_init


class KMeans:
    """Exact K-means with KPynq's multi-level triangle-inequality filters.

    Parameters
    ----------
    n_clusters : K
    algorithm : 'lloyd' | 'hamerly' | 'yinyang'
        'hamerly' = the paper's point-level filter alone (one group);
        'yinyang' = point-level + group-level filters (the full KPynq
        multi-level filter).
    n_groups : group count for 'yinyang' (default K//10, the paper-family
        heuristic).
    init : 'k-means++' | 'random'
    """

    def __init__(self, n_clusters: int, algorithm: str = "yinyang",
                 n_groups: int | None = None, init: str = "k-means++",
                 max_iters: int = 100, tol: float = 1e-4, seed: int = 0):
        if algorithm not in ("lloyd", "hamerly", "yinyang"):
            raise ValueError(f"unknown algorithm {algorithm!r}")
        self.n_clusters = n_clusters
        self.algorithm = algorithm
        self.n_groups = n_groups
        self.init = init
        self.max_iters = max_iters
        self.tol = tol
        self.seed = seed
        self.result_: _km.KMeansResult | None = None

    def _init_centroids(self, points):
        key = jax.random.PRNGKey(self.seed)
        if self.init == "k-means++":
            return kmeans_plusplus(key, points, self.n_clusters)
        return random_init(key, points, self.n_clusters)

    def fit(self, points) -> "KMeans":
        points = jnp.asarray(points)
        init_c = self._init_centroids(points)
        if self.algorithm == "lloyd":
            res = _km.lloyd(points, init_c, self.max_iters, self.tol)
        elif self.algorithm == "hamerly":
            res = _km.yinyang(points, init_c, n_groups=1,
                              max_iters=self.max_iters, tol=self.tol)
        else:
            res = _km.yinyang(points, init_c, n_groups=self.n_groups,
                              max_iters=self.max_iters, tol=self.tol)
        self.result_ = jax.tree.map(jax.device_get, res)
        return self

    # sklearn-style accessors ------------------------------------------------
    @property
    def cluster_centers_(self):
        return self.result_.centroids

    @property
    def labels_(self):
        return self.result_.assignments

    @property
    def inertia_(self):
        return float(self.result_.inertia)

    @property
    def n_iter_(self):
        return int(self.result_.n_iters)

    @property
    def distance_evals_(self):
        """Work-efficiency counter: distance evaluations performed."""
        return float(self.result_.distance_evals)

    def predict(self, points):
        from .distances import pairwise_dists
        d = pairwise_dists(jnp.asarray(points), self.result_.centroids)
        return jax.device_get(jnp.argmin(d, axis=1))
