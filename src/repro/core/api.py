"""Public sklearn-flavoured API for the KPynq K-means family."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import engine as _engine
from . import kmeans as _km
from .init import kmeans_plusplus, random_init


class NotFittedError(ValueError, AttributeError):
    """Raised when results are requested from an unfitted estimator.

    Inherits both ValueError and AttributeError (the sklearn
    convention) so existing ``except AttributeError`` call sites keep
    working while the message actually says what went wrong.
    """


class KMeans:
    """Exact K-means with KPynq's multi-level triangle-inequality filters.

    Parameters
    ----------
    n_clusters : K
    algorithm : 'lloyd' | 'hamerly' | 'yinyang'
        'hamerly' = the paper's point-level filter alone (one group);
        'yinyang' = point-level + group-level filters (the full KPynq
        multi-level filter).
    n_groups : group count for 'yinyang' (default K//10, the paper-family
        heuristic).
    init : 'k-means++' | 'random'
    engine : None | 'auto' | 'oracle' | 'compact' | 'pallas' | 'lloyd'
        None runs the reference ``lax.while_loop`` implementation in
        :mod:`repro.core.kmeans`. Any other value routes the filtered
        algorithms through the device-resident execution engine
        (:mod:`repro.core.engine`), which realises both filter levels
        as skipped work — 'auto' picks the Pallas block-skip kernel on
        TPU and two-level stream compaction elsewhere, EXCEPT tiny
        problems (``n * k <= engine.AUTO_LLOYD_MAX_WORK``), which it
        routes straight to the dense Lloyd loop (measurably faster
        there; same fixed point). Results are identical either way;
        only the wall-clock changes. Ignored for ``algorithm='lloyd'``
        (there is nothing to filter).
    tune : 'auto' | 'off' | 'force'
        Per-(platform, N, K, D) autotuning of the engine configuration
        (:mod:`repro.tune`; cache at ``~/.cache/repro_kmeans_tune.json``
        unless ``REPRO_KMEANS_TUNE_CACHE`` overrides). 'auto' (default)
        uses a cached winner when one exists; 'force' runs the measured
        search on a cache miss (one-time cost, persisted; the STREAMING
        path never measures — there 'force' degrades to 'auto'); 'off'
        uses the engine's built-in defaults. Tuning changes wall-clock
        only — results are bit-identical. Only consulted when
        ``engine`` is not None.
    decay : per-batch count decay for the STREAMING path (see
        :meth:`partial_fit`); unused by :meth:`fit`.
    obs : observability switch (see :mod:`repro.obs`): ``None``/``False``
        off, ``True`` defaults, a ``MetricsRegistry``/``ObsConfig`` for
        control. Engine-path fits record the per-iteration telemetry
        ring into ``stats_`` and publish metrics/events to the
        registry; the streaming path publishes per-batch throughput /
        drift / cache metrics. Results are bit-identical with obs on
        or off.

    After an engine-path :meth:`fit`, ``stats_`` holds the
    :class:`repro.core.engine.EngineStats` (telemetry ring included
    when ``obs`` is enabled); ``None`` otherwise.
    """

    def __init__(self, n_clusters: int, algorithm: str = "yinyang",
                 n_groups: int | None = None, init: str = "k-means++",
                 max_iters: int = 100, tol: float = 1e-4, seed: int = 0,
                 engine: str | None = None, decay: float = 1.0,
                 tune: str = "auto", obs=None):
        if algorithm not in ("lloyd", "hamerly", "yinyang"):
            raise ValueError(f"unknown algorithm {algorithm!r}")
        if engine is not None and engine not in ("auto", "lloyd") \
                and engine not in _engine.BACKENDS:
            raise ValueError(
                f"unknown engine {engine!r}; expected None, 'auto', "
                f"'lloyd' or one of {_engine.BACKENDS}")
        if tune not in ("auto", "off", "force"):
            raise ValueError(f"unknown tune mode {tune!r}; expected "
                             f"'auto', 'off' or 'force'")
        self.n_clusters = n_clusters
        self.algorithm = algorithm
        self.n_groups = n_groups
        self.init = init
        self.max_iters = max_iters
        self.tol = tol
        self.seed = seed
        self.engine = engine
        self.decay = decay
        self.tune = tune
        self.obs = obs
        self.stats_: _engine.EngineStats | None = None
        self.result_: _km.KMeansResult | None = None
        self._stream = None
        self._assign_tables = None  # cached (groups, members, gsize, g)

    def _init_centroids(self, points, weights=None):
        key = jax.random.PRNGKey(self.seed)
        if self.init == "k-means++":
            return kmeans_plusplus(key, points, self.n_clusters,
                                   weights=weights)
        return random_init(key, points, self.n_clusters)

    def fit(self, points, sample_weight=None) -> "KMeans":
        """Batch fit. ``sample_weight``: optional (N,) per-point
        weights — weighted centroid means and inertia through every
        backend, AND weighted D^2 sampling in the k-means++ seeding (a
        weight-m point seeds like m duplicates); the filters are
        weight-independent, so the work saving is unchanged. ``None``
        is bit-identical to uniform weights of 1.0 for the fit and
        runs the seed's original seeding program."""
        points = jnp.asarray(points)
        weights = None if sample_weight is None else \
            jnp.asarray(sample_weight, jnp.float32)
        init_c = self._init_centroids(points, weights)
        self.stats_ = None        # only engine-path fits produce stats
        if self.algorithm == "lloyd":
            res = _km.lloyd(points, init_c, self.max_iters, self.tol,
                            weights=weights)
        else:
            n_groups = 1 if self.algorithm == "hamerly" else self.n_groups
            if self.engine is None:
                res = _km.yinyang(points, init_c, n_groups=n_groups,
                                  max_iters=self.max_iters, tol=self.tol,
                                  weights=weights)
            else:
                out = _engine.fit(points, init_c, n_groups=n_groups,
                                  max_iters=self.max_iters, tol=self.tol,
                                  backend=self.engine, tune=self.tune,
                                  sample_weight=weights, obs=self.obs,
                                  return_stats=True)
                res, self.stats_ = out
        self.result_ = jax.tree.map(jax.device_get, res)
        self._stream = None       # a batch fit supersedes any stream state
        self._assign_tables = None
        return self

    def partial_fit(self, points, shard_id=None,
                    sample_weight=None) -> "KMeans":
        """Streaming mini-batch update (delegates to
        :class:`repro.streaming.StreamingKMeans`).

        Feed point shards one at a time; each batch runs the engine's
        two-level-filtered candidate pass against the current centroids
        and applies a decayed count-weighted (EMA) centroid update.
        ``shard_id`` (any hashable) keys the carried-bounds cache: pass
        it when the same points will be re-presented (e.g. epochs over
        a :class:`repro.data.PointStream`), so triangle-inequality
        bounds survive across batches and skip most distance work on
        revisits.

        Decay schedule: effective per-centroid counts are multiplied by
        ``self.decay`` before each update. ``decay=1.0`` is pure
        count-weighting (per-centroid 1/n learning rate — converges to
        the batch fit on stationary streams); ``decay<1`` forgets with
        a ~``1/(1-decay)``-batch horizon (for drifting streams).

        The first call(s) may only BUFFER points (k-means++ cold-start
        over the first shards); accessors raise ``NotFittedError``
        until enough points arrived. Afterwards ``cluster_centers_``
        etc. track the running stream state; ``inertia_`` is the EWA
        per-point batch cost (an upper-bound estimate), not full-data
        inertia, and ``n_iter_`` counts batches.
        """
        from .. import streaming as _streaming
        if self._stream is None:
            n_groups = 1 if self.algorithm in ("lloyd", "hamerly") \
                else self.n_groups
            self._stream = _streaming.StreamingKMeans(
                self.n_clusters, n_groups=n_groups, init=self.init,
                decay=self.decay, seed=self.seed, tune=self.tune,
                obs=self.obs)
        s = self._stream.partial_fit(points, shard_id=shard_id,
                                     sample_weight=sample_weight)
        if s.initialized:
            self.result_ = _km.KMeansResult(
                s.cluster_centers_, s.labels_,
                np.int32(s.stats_.batches),
                np.float32(s.stats_.distance_evals),
                np.float32(s.ewa_inertia_))
            self._assign_tables = None    # centroids moved this batch
        return self

    def _fitted(self) -> _km.KMeansResult:
        if self.result_ is None:
            raise NotFittedError(
                f"This KMeans instance is not fitted yet; call "
                f"fit() before using this "
                f"{type(self).__name__} attribute/method.")
        return self.result_

    # sklearn-style accessors ------------------------------------------------
    @property
    def cluster_centers_(self):
        return self._fitted().centroids

    @property
    def labels_(self):
        return self._fitted().assignments

    @property
    def inertia_(self):
        return float(self._fitted().inertia)

    @property
    def n_iter_(self):
        return int(self._fitted().n_iters)

    @property
    def distance_evals_(self):
        """Work-efficiency counter: distance evaluations performed."""
        return float(self._fitted().distance_evals)

    # inference ---------------------------------------------------------------

    def _tables(self):
        """Group tables over the FITTED centroids, built once and
        reused by every predict/score call (invalidated by fit /
        partial_fit)."""
        if self._assign_tables is None:
            centroids = jnp.asarray(self._fitted().centroids, jnp.float32)
            g = self.n_groups if self.algorithm == "yinyang" else 1
            groups, members, gsize = _engine.build_assign_tables(
                centroids, g)
            self._assign_tables = (centroids, groups, members, gsize)
        return self._assign_tables

    def _assign(self, points):
        centroids, groups, members, gsize = self._tables()
        return _engine.assign(points, centroids, groups=groups,
                              members=members, gsize=gsize)

    def predict(self, points):
        """Tiled exact nearest-centroid assignment through the PassCore
        candidate pass (``engine.assign``): norm-cached, no O(N*K)
        distance buffer at large N."""
        labels, _ = self._assign(points)
        return jax.device_get(labels)

    def fit_predict(self, points, sample_weight=None):
        """Fit, then return the training assignments (sklearn parity:
        equivalent to ``fit(X).labels_`` but one call)."""
        return self.fit(points, sample_weight=sample_weight).labels_

    def transform(self, points):
        """Distances of ``points`` to every fitted centroid, (N, K) —
        sklearn's cluster-distance space. The output is O(N*K) by
        definition, but it is computed TILED with cached norms, so the
        working set beyond the result stays bounded."""
        from .distances import pairwise_dists, row_norms_sq
        centroids = jnp.asarray(self._fitted().centroids, jnp.float32)
        pts = jnp.asarray(points)
        if pts.dtype != jnp.float32:
            pts = pts.astype(jnp.float32)
        c2 = row_norms_sq(centroids)
        tile = 8192
        out = [pairwise_dists(pts[lo:lo + tile], centroids, None, c2)
               for lo in range(0, pts.shape[0], tile)]
        return jax.device_get(jnp.concatenate(out, axis=0))

    def score(self, points, sample_weight=None):
        """Negative (weighted) inertia of ``points`` under the fitted
        centroids — the sklearn convention (greater is better)."""
        _, dists = self._assign(points)
        d2 = dists * dists
        if sample_weight is not None:
            d2 = d2 * jnp.asarray(sample_weight, jnp.float32)
        return -float(jnp.sum(d2))
