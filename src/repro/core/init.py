"""Centroid initialization: random subset and k-means++ (both jittable)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .distances import pairwise_sq_dists


def random_init(key: jax.Array, points: jnp.ndarray, k: int) -> jnp.ndarray:
    idx = jax.random.choice(key, points.shape[0], shape=(k,), replace=False)
    return points[idx].astype(jnp.float32)


def kmeans_plusplus(key: jax.Array, points: jnp.ndarray, k: int) -> jnp.ndarray:
    """k-means++ seeding (Arthur & Vassilvitskii) as a lax.fori_loop."""
    n = points.shape[0]
    pts = points.astype(jnp.float32)
    key, sub = jax.random.split(key)
    first = pts[jax.random.randint(sub, (), 0, n)]
    centroids = jnp.zeros((k, pts.shape[1]), jnp.float32).at[0].set(first)
    min_d2 = pairwise_sq_dists(pts, first[None])[:, 0]

    def body(i, carry):
        key, centroids, min_d2 = carry
        key, sub = jax.random.split(key)
        # Sample proportional to D^2 (guard the all-zero corner case).
        probs = jnp.where(jnp.sum(min_d2) > 0, min_d2, jnp.ones_like(min_d2))
        idx = jax.random.categorical(sub, jnp.log(probs + 1e-30))
        c = pts[idx]
        centroids = centroids.at[i].set(c)
        d2 = pairwise_sq_dists(pts, c[None])[:, 0]
        return key, centroids, jnp.minimum(min_d2, d2)

    _, centroids, _ = jax.lax.fori_loop(1, k, body, (key, centroids, min_d2))
    return centroids
