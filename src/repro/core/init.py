"""Centroid initialization: random subset and k-means++ (both jittable)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .distances import pairwise_sq_dists


def random_init(key: jax.Array, points: jnp.ndarray, k: int) -> jnp.ndarray:
    idx = jax.random.choice(key, points.shape[0], shape=(k,), replace=False)
    return points[idx].astype(jnp.float32)


def kmeans_plusplus(key: jax.Array, points: jnp.ndarray, k: int,
                    weights: jnp.ndarray | None = None) -> jnp.ndarray:
    """k-means++ seeding (Arthur & Vassilvitskii) as a lax.fori_loop.

    ``weights``: optional (N,) nonnegative per-point weights. The first
    centroid is drawn proportional to w, each subsequent one
    proportional to w * D^2 — the weighted-dataset semantics where a
    point of weight m behaves like m unit-weight duplicates (the exact
    distribution; individual draws differ because the sample space
    collapses m duplicates into one index). ``weights=None`` keeps the
    seed's original program — uniform first draw via randint, plain D^2
    after — so existing fits stay bit-identical.
    """
    n = points.shape[0]
    pts = points.astype(jnp.float32)
    key, sub = jax.random.split(key)
    if weights is None:
        first_idx = jax.random.randint(sub, (), 0, n)
        w = None
    else:
        w = jnp.maximum(jnp.asarray(weights, jnp.float32), 0.0)
        wp = jnp.where(jnp.sum(w) > 0, w, jnp.ones_like(w))
        first_idx = jax.random.categorical(sub, jnp.log(wp + 1e-30))
    first = pts[first_idx]
    centroids = jnp.zeros((k, pts.shape[1]), jnp.float32).at[0].set(first)
    min_d2 = pairwise_sq_dists(pts, first[None])[:, 0]

    def body(i, carry):
        key, centroids, min_d2 = carry
        key, sub = jax.random.split(key)
        # Sample proportional to (w *) D^2 (guard the all-zero corner).
        scores = min_d2 if w is None else w * min_d2
        probs = jnp.where(jnp.sum(scores) > 0, scores,
                          jnp.ones_like(scores) if w is None else wp)
        idx = jax.random.categorical(sub, jnp.log(probs + 1e-30))
        c = pts[idx]
        centroids = centroids.at[i].set(c)
        d2 = pairwise_sq_dists(pts, c[None])[:, 0]
        return key, centroids, jnp.minimum(min_d2, d2)

    _, centroids, _ = jax.lax.fori_loop(1, k, body, (key, centroids, min_d2))
    return centroids
