"""KPynq core: work-efficient triangle-inequality K-means in JAX."""
from .api import KMeans, NotFittedError
from .distances import pairwise_dists, pairwise_sq_dists, rowwise_dists
from .compact import yinyang_compact
from .distributed import distributed_yinyang, make_mesh
from .engine import EngineConfig, EngineStats, fit as engine_fit
from .init import kmeans_plusplus, random_init
from .kmeans import EvalCount, KMeansResult, group_centroids, lloyd, yinyang

__all__ = [
    "KMeans", "KMeansResult", "NotFittedError", "lloyd", "yinyang",
    "group_centroids", "kmeans_plusplus", "random_init",
    "distributed_yinyang", "make_mesh", "yinyang_compact",
    "engine_fit", "EngineStats",
    "EngineConfig", "EvalCount",
    "pairwise_dists", "pairwise_sq_dists", "rowwise_dists",
]
