"""Device-resident filtered K-means execution engine.

This is the single executor behind the KPynq filter family, replacing
the three divergent drivers (masked-dense oracle, host-synced compact
driver, ad-hoc kernel glue) with one iteration loop that realises BOTH
filter levels as skipped work:

* the whole fit runs under ``lax.while_loop`` — zero host round-trips
  per iteration. The only host syncs are capacity-bucket transitions
  (O(log N) of them, counted in :class:`EngineStats`), not one per
  iteration like the legacy ``yinyang_compact`` driver;
* **point-level compaction**: surviving points are stream-compacted
  into a padded buffer whose capacity comes from a fixed power-of-two
  lattice, so XLA compiles a small, bounded set of programs;
* **centroid-level compaction**: each candidate's *surviving groups*
  are compacted into a padded per-point group bucket and only those
  groups' centroids are gathered for the distance pass — the
  group-level filter becomes skipped FLOPs, not just bookkeeping;
* **norm caching**: ``||x||^2`` is computed ONCE PER FIT and carried
  through the ``lax.while_loop`` (``EngineCarry.x2``); ``||c||^2`` is
  computed once per iteration by :func:`move_and_bounds` and shared by
  the own-distance refresh and the next candidate pass
  (``EngineCarry.c2``). On the compact backend the own-distance
  refresh itself runs on the COMPACTED survivor buffer instead of all
  N rows (``refresh_ub=True`` in :func:`compact_candidate_pass`);
* the Pallas block-skip kernel (``repro.kernels.grouped_assign``) slots
  in as the TPU backend behind the same interface;
* the bucket machinery also exists fully IN-TRACE for hostless loops
  (:func:`cap_ladders` / :func:`select_bucket` /
  :func:`ladder_candidate_pass`): a static capacity lattice switched
  per iteration with ``lax.switch`` — what ``repro.core.distributed``
  runs inside its ``shard_map`` body, where a host sync is not an
  option.

Backend selection (``backend=`` on :func:`fit`):

``"oracle"``
    Masked-dense pass over all N points every iteration — computes every
    distance and discards the filtered ones. Ground truth / debugging.
``"compact"``
    The two-level compaction path above. Default off-TPU: on CPU/GPU
    this is what turns filter rates into wall-clock speedup.
``"pallas"``
    Group-granular block-skip Pallas kernel (``interpret=True`` runs it
    anywhere). Default on TPU, where per-point gathers are hostile but
    skipping whole (tile_n x group) blocks is free.
``"lloyd"``
    The jit-cached reference Lloyd loop — one dense GEMM per
    iteration, no filter bookkeeping. The right call below the
    work crossover (see ``EngineConfig.lloyd_max_work``) and a
    legitimate autotuner outcome for filter-hostile shapes.
``"auto"``
    Consults the tuned configuration (see below) when one exists;
    otherwise ``"lloyd"`` for tiny problems (``n * k <=
    lloyd_max_work``), ``"pallas"`` on TPU, ``"compact"`` elsewhere.

Autotuning (``tune=`` on :func:`fit`): every fixed knob of this engine
— ``tile_n``, ``min_cap``, ``chunk``, the group-gather crossover, the
downshift hysteresis, the backend itself — is a measured choice, and
the right value depends on (platform, N, K, D). ``tune="auto"``
(default) consults the persistent tuning cache
(:mod:`repro.tune`, ``~/.cache/repro_kmeans_tune.json`` unless
``REPRO_KMEANS_TUNE_CACHE`` overrides) and uses the cached winner for
this problem signature; ``tune="force"`` runs the measured search on a
cache miss and persists the winner; ``tune="off"`` uses the built-in
defaults. Tuned configurations change SHAPES AND DISPATCH ONLY — the
fixed point (assignments, inertia) is bit-identical for every
configuration (``tests/test_tune.py`` asserts this).

Every backend is exact: fixed points are identical to Lloyd's
(``tests/test_engine.py`` checks assignments/inertia parity across the
whole matrix). The split-loop construction (candidate pass for
iteration *i* runs at the top of body *i+1*, with a single epilogue
pass after the loop) is what lets the bucket conditions live in the
``while_loop`` *cond* without ever re-doing or skipping work.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .distances import (pairwise_dists, pairwise_sq_dists, row_norms_sq,
                        rowwise_dists)
from .kmeans import (EvalCount, KMeansResult, _init_filter_state,
                     centroid_sums, centroids_from_sums, group_centroids,
                     lloyd)

BACKENDS = ("oracle", "compact", "pallas")

# Default backend="auto" work crossover: problems with n*k at or below
# this route straight to the reference Lloyd loop — BENCH_kmeans.json
# shows the dense (N, K) GEMM beating the filtered engine at uci-small
# scale, where one fused matmul per iteration is cheaper than any bound
# bookkeeping. The fixed point is identical (tests/test_engine.py
# parity matrix), only distance_evals differ. The per-signature tuned
# value lives in EngineConfig.lloyd_max_work.
AUTO_LLOYD_MAX_WORK = 1 << 17

# jit-cached Lloyd for the tiny-problem route: calling the bare
# function would re-trace its while_loop on every fit, costing more
# than the fit itself at these sizes
_lloyd_jit = functools.partial(jax.jit, static_argnames=(
    "max_iters", "tol"))(lambda points, init_c, *, max_iters, tol:
                         lloyd(points, init_c, max_iters, tol))


# --------------------------------------------------------------------------
# engine configuration (the autotuner's search space)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """One point in the engine's configuration space.

    Every field is a measured choice the autotuner (:mod:`repro.tune`)
    searches per (platform, N, K, D) signature; none of them affects
    the fixed point — only shapes, dispatch, and wall-clock.

    backend : "auto" | "oracle" | "compact" | "pallas" | "lloyd"
        Candidate-pass realisation. "auto" defers to the platform /
        ``lloyd_max_work`` rules in :func:`fit`.
    tile_n : point-tile height of the Pallas block-skip kernels.
    min_cap : floor of the power-of-two point-capacity lattice.
    chunk : largest compacted candidate count for which the per-point
        group-gather path is considered (above it the dense GEMM on
        the survivor buffer wins; XLA gathers scale worse than BLAS).
    group_gather_factor : the group-gather path is taken only when
        ``cap_g * l_max * group_gather_factor <= k`` — i.e. the group
        filter must remove at least this multiple of K before
        per-point gathers beat one dense (cap_n, K) matmul.
    down_n / down_g : downshift hysteresis. A running segment exits to
        a smaller bucket when ``n_cand * down_n <= cap_n`` (resp.
        ``gmax * down_g <= cap_g``); 0 disables that downshift axis.
    refresh_in_pass : where the own-distance refresh of *maybe*
        survivors runs on the compact backend. True = on the compacted
        survivor buffer inside the candidate pass (no full-N rowwise
        work, but capacity buckets are sized by the larger maybe-count);
        False = as a full-N masked rowwise pass in
        :func:`move_and_bounds` (costs one gather+dot over N per
        iteration, but the refresh prunes the candidate set BEFORE
        compaction, so buckets track the smaller need-count). Which
        side wins is a measured shape property — gather-hostile wide-D
        problems favour True, GEMM-strong small-D CPU shapes False.
    lloyd_max_work : backend="auto" routes ``n * k <= lloyd_max_work``
        straight to the dense Lloyd loop.
    """
    backend: str = "auto"
    tile_n: int = 256
    min_cap: int = 256
    chunk: int = 2048
    group_gather_factor: int = 4
    down_n: int = 2
    down_g: int = 4
    refresh_in_pass: bool = False
    lloyd_max_work: int = AUTO_LLOYD_MAX_WORK

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "EngineConfig":
        """Tolerant inverse of :meth:`to_dict` (unknown keys from a
        newer/older cache version are dropped, missing keys default)."""
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in names})

    def replace(self, **kw) -> "EngineConfig":
        return dataclasses.replace(self, **kw)


DEFAULT_CONFIG = EngineConfig()


def use_groups_decision(*, cap_n: int, cap_g: int, l_max: int, k: int,
                        chunk: int, group_gather_factor: int) -> bool:
    """The compact pass's group-gather vs dense-GEMM crossover — THE
    single copy of the rule, shared by the pass (trace-time), the
    driver (per-segment stats), and the tuner (search space)."""
    return (cap_g * l_max * group_gather_factor <= k) and cap_n <= chunk


# --------------------------------------------------------------------------
# shared per-iteration pieces (also consumed by compact.py / distributed.py)
# --------------------------------------------------------------------------

def move_and_bounds(points, centroids, assignments, ub, lb, groups,
                    *, k: int, n_groups: int, reduce_sums=None,
                    x2=None, refresh: bool = True):
    """Centroid move + triangle-inequality bound maintenance + the
    point-level filter. Pure traced function shared by every driver.

    ``reduce_sums``: optional ``(sums, counts) -> (sums, counts)`` hook
    applied to the per-shard centroid partial sums (``lax.psum`` in the
    distributed fit; identity locally).

    ``x2``: cached ``||x||^2`` row norms (computed once per fit by the
    callers); ``None`` falls back to the diff-form rowwise distance.
    The new centroids' ``||c||^2`` is computed here ONCE and returned
    (``new_c2``) so the caller can share it with the following
    candidate pass instead of recomputing it.

    ``refresh=False`` (the compact backend) skips the own-distance
    refresh entirely — the returned ``need`` is then the *maybe* mask
    (``ub > glb`` on drift-inflated bounds) and the refresh happens on
    the compacted survivor buffer inside
    :func:`compact_candidate_pass` (``refresh_ub=True``), so the
    full-N gather + rowwise pass disappears from the hot loop.

    Returns ``(new_c, new_c2, ub_t, lb_dec, need, shift, n_tightened)``
    where ``need`` marks points that must enter the candidate distance
    pass and ``n_tightened`` counts the own-distance refreshes this
    decision implies (performed here when ``refresh``, else by the
    candidate pass).
    """
    sums, counts = centroid_sums(points, assignments, k)
    if reduce_sums is not None:
        sums, counts = reduce_sums(sums, counts)
    new_c = centroids_from_sums(sums, counts, centroids)
    new_c2 = row_norms_sq(new_c)                       # once per iteration

    drift = jnp.linalg.norm(new_c - centroids, axis=-1)
    group_drift = jax.ops.segment_max(drift, groups, num_segments=n_groups)
    shift = jnp.max(drift)
    ub = ub + drift[assignments]
    lb_dec = jnp.maximum(lb - group_drift[None, :], 0.0)
    glb = jnp.min(lb_dec, axis=1)
    maybe = ub > glb
    if refresh:
        if x2 is None:
            d_own = rowwise_dists(points, new_c[assignments])
        else:
            own = new_c[assignments]
            d_own = jnp.sqrt(jnp.maximum(
                x2 - 2.0 * jnp.sum(points.astype(jnp.float32) * own,
                                   axis=-1) + new_c2[assignments], 0.0))
        ub_t = jnp.where(maybe, d_own, ub)
        need = ub_t > glb
    else:
        ub_t = ub
        need = maybe
    return new_c, new_c2, ub_t, lb_dec, need, shift, jnp.sum(
        maybe.astype(jnp.float32))


def dense_candidate_pass(points, new_c, assignments, ub_t, lb, groups, need,
                         *, n_groups: int, opt_sq: bool = True,
                         x2=None, c2=None):
    """Masked-dense candidate pass over all N points (oracle backend and
    the per-shard distributed step). Group filter applied as a mask —
    exact semantics, no skipped FLOPs.

    ``opt_sq=True`` (default) runs min/argmin on SQUARED distances and
    sqrts only the reduced outputs (monotone => bit-identical results,
    one fewer (N, K) sqrt pass + HBM round-trip). ``x2``/``c2``:
    cached squared norms (see :mod:`repro.core.distances`).

    Returns ``(new_assign, new_ub, new_lb, n_pairs)``.
    """
    n = points.shape[0]
    rows = jnp.arange(n)
    group_need = need[:, None] & (lb < ub_t[:, None])              # (N, G)
    cand = group_need[:, groups]                                    # (N, K)
    pairs = jnp.sum(cand.astype(jnp.float32))

    if opt_sq:
        d_cand = jnp.where(cand, pairwise_sq_dists(points, new_c, x2, c2),
                           jnp.inf)
        best = jnp.argmin(d_cand, axis=1).astype(jnp.int32)
        best_d = jnp.sqrt(jnp.min(d_cand, axis=1))
    else:
        d_cand = jnp.where(cand, pairwise_dists(points, new_c, x2, c2),
                           jnp.inf)
        best = jnp.argmin(d_cand, axis=1).astype(jnp.int32)
        best_d = jnp.min(d_cand, axis=1)
    changed = best_d < ub_t
    new_assign = jnp.where(changed, best, assignments)
    new_ub = jnp.minimum(ub_t, best_d)

    d_excl = d_cand.at[rows, new_assign].set(jnp.inf)
    lb_comp = jax.ops.segment_min(d_excl.T, groups,
                                  num_segments=n_groups).T          # (N, G)
    if opt_sq:
        lb_comp = jnp.sqrt(lb_comp)
    new_lb = jnp.where(group_need, lb_comp, lb)
    old_group = groups[assignments]
    new_lb = new_lb.at[rows, old_group].min(
        jnp.where(changed, ub_t, jnp.inf))
    return new_assign, new_ub, new_lb, pairs


def compact_candidate_pass(points, new_c, assignments, ub_t, lb, groups,
                           members, gsize, need, *, cap_n: int, cap_g: int,
                           n_groups: int, chunk: int = 2048,
                           use_groups: bool | None = None,
                           opt_sq: bool = True, x2=None, c2=None,
                           refresh_ub: bool = False,
                           group_gather_factor: int = 4):
    """Two-level compacted candidate pass.

    Point level: the ``need`` survivors are stream-compacted into a
    ``cap_n`` buffer (``cap_n`` must be >= the survivor count — the
    engine's while-loop cond guarantees it).

    ``refresh_ub=True`` (the engine's compact backend): ``need`` is the
    *maybe* mask from :func:`move_and_bounds` ``refresh=False`` and the
    exact own-centroid distance is computed HERE, on the compacted
    buffer only — points whose refreshed bound re-filters them simply
    flow through with a tightened ``ub`` and an empty group set (their
    distance rows are masked out), so the full-N rowwise refresh is
    gone while the semantics stay bit-identical.

    Centroid level: each candidate's surviving groups are compacted
    into a ``cap_g``-slot bucket; only those groups' member centroids
    (``members``: (G, Lmax) int32, -1-padded) are gathered and scored.
    The gather-vs-GEMM crossover is :func:`use_groups_decision` (tuned
    via ``group_gather_factor`` / ``chunk`` — see
    :class:`EngineConfig`); ``use_groups=None`` applies it at trace
    time. When the bucket IS compiled in, a runtime ``lax.cond``
    spills to the dense branch whenever some candidate's
    surviving-group count exceeds ``cap_g`` — exactness never depends
    on the bucket guess; the engine reads the returned ``gmax`` to
    upshift the next segment.

    ``x2``/``c2``: cached squared norms (full-size ``x2`` is gathered
    per survivor; ``c2`` is this iteration's centroid norms from
    :func:`move_and_bounds`).

    Returns updated full-size ``(assignments, ub, lb, n_pairs, gmax)``.
    """
    n = points.shape[0]
    k = new_c.shape[0]
    l_max = members.shape[1]
    rows = jnp.arange(cap_n)

    # --- point-level compaction -------------------------------------
    pos = jnp.cumsum(need.astype(jnp.int32)) - 1
    slot = jnp.where(need, pos, cap_n)
    idx = jnp.zeros((cap_n,), jnp.int32).at[slot].set(
        jnp.arange(n, dtype=jnp.int32), mode="drop")
    count = jnp.sum(need.astype(jnp.int32))
    valid = jnp.arange(cap_n) < count

    cpts = points[idx]                                        # (cap, D)
    c_ub = ub_t[idx]
    c_lb = lb[idx]                                            # (cap, G)
    c_as = assignments[idx]
    if c2 is None:
        c2 = row_norms_sq(new_c)
    c_x2 = x2[idx] if x2 is not None else row_norms_sq(cpts)  # (cap,)
    if refresh_ub:
        # own-distance refresh on the compacted buffer (cap_n rows, not
        # N): d(x, c_a) via the cached norms; invalid slots compute
        # garbage that the scatter drops
        own = new_c[c_as]
        c_ub = jnp.sqrt(jnp.maximum(
            c_x2 - 2.0 * jnp.sum(cpts.astype(jnp.float32) * own, axis=-1)
            + c2[c_as], 0.0))
    gneed = (c_lb < c_ub[:, None]) & valid[:, None]           # (cap, G)
    gmax = jnp.max(jnp.sum(gneed.astype(jnp.int32), axis=1))
    # rows that still need any distance work after the (possibly
    # in-pass) refresh — the dense branch's honest eval count
    n_rows = jnp.sum(jnp.any(gneed, axis=1).astype(jnp.float32))

    if use_groups is None:
        use_groups = use_groups_decision(
            cap_n=cap_n, cap_g=cap_g, l_max=l_max, k=k, chunk=chunk,
            group_gather_factor=group_gather_factor)

    def dense_branch(_):
        # one (cap_n, K) GEMM on the survivors
        gmask = gneed[:, groups]                              # (cap, K)
        if opt_sq:
            # min/argmin on squared distances (monotone => identical),
            # sqrt only the (cap,)/(cap, G) reductions: one fewer
            # (cap, K) sqrt pass per iteration.
            d_cand = jnp.where(gmask,
                               pairwise_sq_dists(cpts, new_c, c_x2, c2),
                               jnp.inf)
            bid = jnp.argmin(d_cand, axis=1).astype(jnp.int32)
            bd = jnp.sqrt(jnp.min(d_cand, axis=1))
        else:
            d_cand = jnp.where(gmask,
                               pairwise_dists(cpts, new_c, c_x2, c2),
                               jnp.inf)
            bid = jnp.argmin(d_cand, axis=1).astype(jnp.int32)
            bd = jnp.min(d_cand, axis=1)
        chg = bd < c_ub
        nas = jnp.where(chg, bid, c_as)
        nub = jnp.minimum(c_ub, bd)
        d_excl = d_cand.at[rows, nas].set(jnp.inf)
        lb_comp = jax.ops.segment_min(d_excl.T, groups,
                                      num_segments=n_groups).T
        if opt_sq:
            lb_comp = jnp.sqrt(lb_comp)
        new_clb = jnp.where(gneed, lb_comp, c_lb)
        pairs = n_rows * k
        return nas, nub, new_clb, pairs, chg

    def group_branch(_):
        # centroid-level compaction: padded per-point group bucket
        gpos = jnp.cumsum(gneed.astype(jnp.int32), axis=1) - 1
        gslot = jnp.where(gneed, gpos, cap_g)
        gsel = jnp.full((cap_n, cap_g), n_groups, jnp.int32).at[
            rows[:, None], gslot].set(
            jnp.broadcast_to(jnp.arange(n_groups, dtype=jnp.int32),
                             (cap_n, n_groups)), mode="drop")

        def bucket_pass(x, x2v, gs, cub, cas):
            mem = jnp.take(members, gs, axis=0, mode="fill",
                           fill_value=-1)                # (ch, cap_g, L)
            mem_s = jnp.maximum(mem, 0)
            csel = new_c[mem_s]                          # (ch, cap_g, L, D)
            xf = x.astype(jnp.float32)
            cross = jnp.einsum("nd,ngld->ngl", xf,
                               csel.astype(jnp.float32))
            d2 = jnp.maximum(x2v[:, None, None] - 2.0 * cross + c2[mem_s],
                             0.0)
            ch = x.shape[0]
            # squared-distance reductions, sqrt only the outputs
            dm = jnp.where(mem >= 0, d2, jnp.inf).reshape(ch, -1)
            memf = mem.reshape(ch, -1)
            bcol = jnp.argmin(dm, axis=1)
            bd = jnp.sqrt(jnp.min(dm, axis=1))
            bid = jnp.take_along_axis(memf, bcol[:, None], 1)[:, 0]
            chg = bd < cub
            nas = jnp.where(chg, bid, cas).astype(jnp.int32)
            nub = jnp.minimum(cub, bd)
            d_ex = jnp.where(memf == nas[:, None], jnp.inf, dm)
            smin = jnp.sqrt(jnp.min(d_ex.reshape(ch, cap_g, l_max),
                                    axis=2))
            return nas, nub, smin, chg

        nas, nub, smin, chg = bucket_pass(cpts, c_x2, gsel, c_ub, c_as)
        new_clb = c_lb.at[rows[:, None], gsel].set(smin, mode="drop")
        pairs = jnp.sum(gneed.astype(jnp.float32) * gsize[None, :])
        return nas, nub, new_clb, pairs, chg

    if use_groups:
        nas, nub, new_clb, pairs, chg = jax.lax.cond(
            gmax <= cap_g, group_branch, dense_branch, operand=None)
    else:
        nas, nub, new_clb, pairs, chg = dense_branch(None)

    old_group = jnp.take(groups, c_as)                        # (cap,)
    new_clb = new_clb.at[rows, old_group].min(
        jnp.where(chg, c_ub, jnp.inf))

    # --- scatter survivors back (invalid slots dropped) --------------
    sidx = jnp.where(valid, idx, n)
    assignments = assignments.at[sidx].set(nas, mode="drop")
    ub_out = ub_t.at[sidx].set(nub, mode="drop")
    lb_out = lb.at[sidx].set(new_clb, mode="drop")
    return assignments, ub_out, lb_out, pairs, gmax


def cap_ladders(n: int, n_groups: int, *, min_cap: int = 256,
                max_branches: int = 12):
    """Static (cap_n, cap_g) lattices for the IN-TRACE bucketed pass.

    The batch driver picks capacities on the host between ``_run_loop``
    segments; inside a ``shard_map`` body there is no host to ask, so
    the whole lattice must be fixed at trace time and the shard switches
    between levels with ``lax.switch`` (:func:`ladder_candidate_pass`).
    Levels are the engine's usual power-of-two lattice from ``min_cap``
    up to the shard size (resp. 1 up to ``n_groups``), coarsened until
    the branch product fits ``max_branches`` compiled pass instances:
    interior levels go first, then (only under a budget too small for
    2x2 ladders) the LOW endpoints. The top levels are never dropped —
    ``cap_ns[-1] == n`` is what makes the mandatory upshift in
    :func:`select_bucket` always able to satisfy the pass's
    ``cap_n >= count`` precondition.
    """
    n = max(int(n), 1)
    n_groups = max(int(n_groups), 1)
    cap_ns, c = [], min(_bucket_cap(min_cap, 1, n), n)
    while c < n:
        cap_ns.append(c)
        c *= 2
    cap_ns.append(n)
    cap_gs, g = [], 1
    while g < n_groups:
        cap_gs.append(g)
        g *= 2
    cap_gs.append(n_groups)
    while len(cap_ns) * len(cap_gs) > max(int(max_branches), 1):
        if len(cap_gs) > 2 and len(cap_gs) >= len(cap_ns):
            del cap_gs[len(cap_gs) // 2]
        elif len(cap_ns) > 2:
            del cap_ns[len(cap_ns) // 2]
        elif len(cap_gs) > 1:
            del cap_gs[0]
        elif len(cap_ns) > 1:
            del cap_ns[0]
        else:
            break
    return tuple(cap_ns), tuple(cap_gs)


def select_bucket(n_cand, gmax, level_n, level_g, *, cap_ns, cap_gs,
                  down_n: int = 2, down_g: int = 4):
    """Shard-local bucket transition — the traced analogue of the host
    bucket picker in :func:`fit`.

    Upshifts are mandatory the moment the pending candidate count (or
    the observed surviving-group high-water) leaves its level;
    downshifts only fire past the tuned hysteresis factors
    (``EngineConfig.down_n`` / ``down_g``; 0 disables that axis), and
    never on ``gmax == 0`` (no candidates seen — not evidence that one
    group slot suffices). Returns the next ``(level_n, level_g)``.
    """
    cn = jnp.asarray(cap_ns, jnp.int32)
    cg = jnp.asarray(cap_gs, jnp.int32)
    req_n = jnp.minimum(jnp.searchsorted(cn, n_cand),
                        len(cap_ns) - 1).astype(jnp.int32)
    move = req_n > level_n
    if down_n:
        move = jnp.logical_or(move, jnp.logical_and(
            req_n < level_n, n_cand * down_n <= cn[level_n]))
    new_n = jnp.where(move, req_n, level_n)

    req_g = jnp.minimum(jnp.searchsorted(cg, jnp.maximum(gmax, 1)),
                        len(cap_gs) - 1).astype(jnp.int32)
    move_g = req_g > level_g
    if down_g:
        move_g = jnp.logical_or(move_g, jnp.logical_and(
            jnp.logical_and(gmax > 0, req_g < level_g),
            gmax * down_g <= cg[level_g]))
    new_g = jnp.where(move_g, req_g, level_g)
    return new_n, new_g


def ladder_candidate_pass(points, new_c, assignments, ub_t, lb, groups,
                          members, gsize, need, level_n, level_g, *,
                          cap_ns, cap_gs, n_groups: int, chunk: int = 2048,
                          group_gather_factor: int = 4, opt_sq: bool = True,
                          x2=None, c2=None, refresh_ub: bool = False):
    """:func:`compact_candidate_pass` at a TRACED capacity level.

    One ``lax.switch`` over the static ``cap_ns`` x ``cap_gs`` lattice
    (:func:`cap_ladders`); each branch is the compact pass compiled at
    one (cap_n, cap_g) pair, with the gather-vs-GEMM crossover
    (:func:`use_groups_decision`) resolved per branch at trace time.
    This is what lets a ``shard_map`` body run the two-level compaction
    with SHARD-LOCAL bucket choices and zero host syncs: every shard
    executes only its selected branch, and no collectives live inside
    the branches so shards in different buckets cannot desynchronise.
    Correctness needs ``cap_ns[level_n] >= sum(need)`` — the mandatory
    upshift in :func:`select_bucket` maintains it; ``cap_g`` stays a
    guess (the pass's ``lax.cond`` spills to its dense branch).
    """
    branches = []
    for cn in cap_ns:
        for cg in cap_gs:
            def branch(_, cn=cn, cg=cg):
                return compact_candidate_pass(
                    points, new_c, assignments, ub_t, lb, groups, members,
                    gsize, need, cap_n=cn, cap_g=cg, n_groups=n_groups,
                    chunk=chunk, use_groups=None, opt_sq=opt_sq, x2=x2,
                    c2=c2, refresh_ub=refresh_ub,
                    group_gather_factor=group_gather_factor)
            branches.append(branch)
    if len(branches) == 1:
        return branches[0](None)
    index = level_n * len(cap_gs) + level_g
    return jax.lax.switch(index, branches, None)


def pallas_candidate_pass(points, new_c, assignments, ub_t, lb, groups,
                          members, gsize, need, *, n_groups: int,
                          tile_n: int = 256, interpret: bool = False,
                          x2=None, c2=None):
    """Candidate pass through the grouped block-skip Pallas kernel.

    The (point, group) filter decisions become a (N/tile_n, G) block
    mask; the kernel runs the distance matmul only for live blocks and
    returns the global (min, argmin) plus per-group (min, argmin,
    second-min) — exactly what the Yinyang lower-bound refresh needs,
    with no (N, K) distance matrix ever materialised. Cached squared
    norms (``x2`` per point, ``c2`` per centroid) are threaded into
    the kernel so it never recomputes them.
    """
    from ..kernels import build_group_block_mask, grouped_assign

    n = points.shape[0]
    rows = jnp.arange(n)
    group_need = need[:, None] & (lb < ub_t[:, None])              # (N, G)
    mask = build_group_block_mask(group_need, tile_n=tile_n)       # (gn, G)
    mem_s = jnp.maximum(members, 0)
    c_grouped = new_c[mem_s]                                # (G, Lmax, D)
    c2g = None if c2 is None else c2[mem_s]                 # (G, Lmax)
    best2, idx, gmin, garg, gmin2 = grouped_assign(
        points, c_grouped, members, mask, tile_n=tile_n,
        interpret=interpret, x2=x2, c2g=c2g)

    best_d = jnp.sqrt(best2)
    changed = best_d < ub_t
    new_assign = jnp.where(changed, idx, assignments)
    new_ub = jnp.minimum(ub_t, best_d)

    # per-group min excluding the (new) assigned centroid: the group
    # argmin collides with the assignment iff the assignment came from
    # that group, in which case the second-min is the excluded min.
    lb_comp = jnp.sqrt(jnp.where(garg == new_assign[:, None], gmin2, gmin))
    new_lb = jnp.where(group_need, lb_comp, lb)
    old_group = groups[assignments]
    new_lb = new_lb.at[rows, old_group].min(
        jnp.where(changed, ub_t, jnp.inf))
    pairs = jnp.float32(tile_n) * jnp.sum(
        mask.astype(jnp.float32) * gsize[None, :])
    return new_assign, new_ub, new_lb, pairs


# --------------------------------------------------------------------------
# the device-resident loop
# --------------------------------------------------------------------------

class EngineCarry(NamedTuple):
    """while_loop carry. ``ub``/``lb``/``need`` describe the PENDING
    candidate pass (iteration ``iteration``'s second half), which the
    next loop body — or the epilogue — executes. ``x2`` is the
    fit-constant point norms; ``c2`` is the CURRENT centroids' norms
    (refreshed once per iteration by :func:`move_and_bounds`)."""
    iteration: jnp.ndarray    # int32: completed move+bounds iterations
    centroids: jnp.ndarray    # (K, D)
    c2: jnp.ndarray           # (K,) ||centroids||^2, once per iteration
    assignments: jnp.ndarray  # (N,)
    ub: jnp.ndarray           # (N,) tightened upper bounds
    lb: jnp.ndarray           # (N, G) decayed lower bounds
    x2: jnp.ndarray           # (N,) ||x||^2, computed ONCE per fit
    need: jnp.ndarray         # (N,) pending candidate mask
    n_cand: jnp.ndarray       # int32 = sum(need)
    gmax: jnp.ndarray         # int32 max surviving groups per candidate,
                              # as observed by the LAST executed pass
    shift: jnp.ndarray        # f32 max centroid drift
    evals: EvalCount


@dataclasses.dataclass
class EngineStats:
    """Execution telemetry: the 'no per-iteration host sync' claim is
    checkable as ``host_syncs << n_iters``; ``use_groups`` records the
    gather-vs-GEMM decision per compact segment (parallel to
    ``caps_history``); ``x2_evals`` states the norm-carry contract of
    the constructed trace — ``||x||^2`` enters via ``EngineCarry.x2``
    so exactly one full-N norm computation exists per fit by
    construction (it is structural, not a runtime counter;
    ``tests/test_tune.py`` verifies it by counting real
    ``row_norms_sq`` calls); ``config`` is the resolved
    :class:`EngineConfig` actually used."""
    backend: str = ""
    n_iters: int = 0
    host_syncs: int = 0
    bucket_switches: int = 0
    caps_history: list = dataclasses.field(default_factory=list)
    use_groups: list = dataclasses.field(default_factory=list)
    x2_evals: int = 0
    config: dict = dataclasses.field(default_factory=dict)


def _candidate_pass(backend, points, carry, groups, members, gsize, *,
                    n_groups, cap_n, cap_g, chunk, tile_n, interpret,
                    use_groups, group_gather_factor,
                    refresh_in_pass=False):
    """Backend dispatch, normalised to (assign, ub, lb, pairs, gmax)."""
    if backend == "oracle":
        out = dense_candidate_pass(
            points, carry.centroids, carry.assignments, carry.ub, carry.lb,
            groups, carry.need, n_groups=n_groups, x2=carry.x2, c2=carry.c2)
        return out + (jnp.int32(0),)
    if backend == "pallas":
        out = pallas_candidate_pass(
            points, carry.centroids, carry.assignments, carry.ub, carry.lb,
            groups, members, gsize, carry.need, n_groups=n_groups,
            tile_n=tile_n, interpret=interpret, x2=carry.x2, c2=carry.c2)
        return out + (jnp.int32(0),)
    return compact_candidate_pass(
        points, carry.centroids, carry.assignments, carry.ub, carry.lb,
        groups, members, gsize, carry.need, cap_n=cap_n, cap_g=cap_g,
        n_groups=n_groups, chunk=chunk, opt_sq=True, x2=carry.x2,
        c2=carry.c2, refresh_ub=refresh_in_pass, use_groups=use_groups,
        group_gather_factor=group_gather_factor)


@functools.partial(jax.jit, static_argnames=(
    "backend", "k", "n_groups", "cap_n", "cap_g", "max_iters", "tol",
    "min_cap", "allow_downshift", "chunk", "tile_n", "interpret",
    "use_groups", "group_gather_factor", "down_n", "down_g",
    "refresh_in_pass"))
def _run_loop(points, carry, groups, members, gsize, *, backend, k,
              n_groups, cap_n, cap_g, max_iters, tol, min_cap,
              allow_downshift, chunk, tile_n, interpret, use_groups=None,
              group_gather_factor=4, down_n=2, down_g=4,
              refresh_in_pass=False):
    """One capacity bucket's worth of device-resident iterations.

    Exits when converged / out of iterations (terminal), or — compact
    backend only — when the pending candidate count leaves its bucket
    ((cap/2, cap] for points, (cap/4, cap] for group slots), at which
    point the host picks the next bucket from the exit scalars. That
    is the ONLY host sync."""

    def cond(c):
        active = jnp.logical_and(c.iteration < max_iters, c.shift > tol)
        if backend != "compact":
            return active
        fits = jnp.logical_and(c.n_cand <= cap_n, c.gmax <= cap_g)
        ok = jnp.logical_and(active, fits)
        if allow_downshift and (down_n or down_g):
            # exit when a strictly smaller point bucket would fit — the
            # candidate pass is linear in cap_n, so one sync (~ms) buys
            # back every decay-phase iteration's padding. The group cap
            # only affects the bucketed pass's minor axis; chase it
            # lazily to avoid segment churn. The factors are the tuned
            # hysteresis (EngineConfig.down_n / down_g; 0 disables).
            down = jnp.bool_(False)
            if down_n:
                down = jnp.logical_or(down, jnp.logical_and(
                    c.n_cand * down_n <= cap_n, cap_n > min_cap))
            if down_g:
                # gmax == 0 means the last pass saw no candidates, not
                # that one group slot suffices — never downshift on it
                down = jnp.logical_or(down, jnp.logical_and(
                    jnp.logical_and(c.gmax > 0,
                                    c.gmax * down_g <= cap_g),
                    cap_g > 1))
            ok = jnp.logical_and(ok, jnp.logical_not(down))
        return ok

    def body(c):
        new_as, new_ub, new_lb, pairs, gmax = _candidate_pass(
            backend, points, c, groups, members, gsize, n_groups=n_groups,
            cap_n=cap_n, cap_g=cap_g, chunk=chunk, tile_n=tile_n,
            interpret=interpret, use_groups=use_groups,
            group_gather_factor=group_gather_factor,
            refresh_in_pass=refresh_in_pass)
        new_c, new_c2, ub_t, lb_dec, need, shift, tightened = \
            move_and_bounds(points, c.centroids, new_as, new_ub, new_lb,
                            groups, k=k, n_groups=n_groups, x2=c.x2,
                            refresh=not (backend == "compact"
                                         and refresh_in_pass))
        n_cand = jnp.sum(need.astype(jnp.int32))
        return EngineCarry(c.iteration + 1, new_c, new_c2, new_as, ub_t,
                           lb_dec, c.x2, need, n_cand, gmax, shift,
                           c.evals.add(pairs).add(tightened))

    return jax.lax.while_loop(cond, body, carry)


@functools.partial(jax.jit, static_argnames=(
    "backend", "n_groups", "cap_n", "cap_g", "chunk", "tile_n",
    "interpret", "use_groups", "group_gather_factor", "refresh_in_pass"))
def _epilogue(points, carry, groups, members, gsize, *, backend, n_groups,
              cap_n, cap_g, chunk, tile_n, interpret, use_groups=None,
              group_gather_factor=4, refresh_in_pass=False):
    """Final pending candidate pass + inertia, fused into one program."""
    new_as, _, _, pairs, _ = _candidate_pass(
        backend, points, carry, groups, members, gsize, n_groups=n_groups,
        cap_n=cap_n, cap_g=cap_g, chunk=chunk, tile_n=tile_n,
        interpret=interpret, use_groups=use_groups,
        group_gather_factor=group_gather_factor,
        refresh_in_pass=refresh_in_pass)
    evals = carry.evals.add(pairs)
    d = rowwise_dists(points, carry.centroids[new_as])
    return new_as, evals.total(), jnp.sum(d * d)


@functools.partial(jax.jit, static_argnames=("n_groups",))
def _init_carry(points, init_c, groups, *, n_groups):
    """Fused setup: point norms (THE once-per-fit ``||x||^2``), initial
    filter state, and the initial loop carry — one dispatch instead of
    the ~8 eager ops the old driver issued per fit."""
    n = points.shape[0]
    x2 = row_norms_sq(points)
    c2 = row_norms_sq(init_c.astype(jnp.float32))
    state0 = _init_filter_state(points, init_c, groups, n_groups,
                                x2=x2, c2=c2)
    return EngineCarry(
        jnp.int32(0), state0.centroids, c2, state0.assignments, state0.ub,
        state0.lb, x2, jnp.zeros((n,), bool), jnp.int32(0), jnp.int32(0),
        jnp.float32(jnp.inf), state0.distance_evals)


@functools.partial(jax.jit, static_argnames=(
    "backend", "k", "n_groups", "max_iters", "tol", "chunk", "tile_n",
    "interpret", "use_groups", "group_gather_factor", "refresh_in_pass"))
def _fit_fused(points, init_c, *, backend, k, n_groups, max_iters, tol,
               chunk, tile_n, interpret, use_groups=None,
               group_gather_factor=4, refresh_in_pass=False):
    """Whole fit — grouping, init, loop, epilogue — as ONE program.

    Used for small problems (and exercised by tests for every backend):
    at a few thousand points the ~10 eager setup dispatches of the
    bucketed driver cost more than the entire fit, so run a single
    full-capacity segment with the group-membership table built on
    device (Lmax = K upper bound; fine at small K). Reuses _run_loop /
    _epilogue — at full capacities their bucket conditions are
    vacuous, so nesting them in this jit inlines to one program."""
    n = points.shape[0]
    groups = group_centroids(init_c, n_groups)
    # device-side (G, K) membership table: row g lists group g's
    # centroids in ascending order, -1-padded
    order = jnp.argsort(groups, stable=True)
    sg = groups[order]
    starts = jnp.searchsorted(sg, jnp.arange(n_groups))
    rank = jnp.arange(k) - starts[sg]
    members = jnp.full((n_groups, k), -1, jnp.int32).at[
        sg, rank].set(order.astype(jnp.int32))
    gsize = jax.ops.segment_sum(jnp.ones((k,), jnp.float32), groups,
                                num_segments=n_groups)

    carry = _init_carry(points, init_c, groups, n_groups=n_groups)
    carry = _run_loop(points, carry, groups, members, gsize,
                      backend=backend, k=k, n_groups=n_groups, cap_n=n,
                      cap_g=n_groups, max_iters=max_iters, tol=tol,
                      min_cap=n, allow_downshift=False, chunk=chunk,
                      tile_n=tile_n, interpret=interpret,
                      use_groups=use_groups,
                      group_gather_factor=group_gather_factor,
                      refresh_in_pass=refresh_in_pass)
    new_as, evals, inertia = _epilogue(
        points, carry, groups, members, gsize, backend=backend,
        n_groups=n_groups, cap_n=n, cap_g=n_groups, chunk=chunk,
        tile_n=tile_n, interpret=interpret, use_groups=use_groups,
        group_gather_factor=group_gather_factor,
        refresh_in_pass=refresh_in_pass)
    return carry.centroids, new_as, carry.iteration, evals, inertia


def _bucket_cap(count: int, floor: int, ceil: int) -> int:
    """Smallest power-of-two >= count, clamped to [floor, ceil]. The
    lattice keeps the set of compiled programs small and reusable."""
    cap = 1 << (max(int(count), 1) - 1).bit_length()
    return max(min(cap, ceil), min(floor, ceil))


def build_group_tables(groups_np: np.ndarray, n_groups: int):
    """Host-side group tables: (G, Lmax) -1-padded membership matrix +
    fp32 group sizes. Shared by the batch fit and the streaming step."""
    counts = np.bincount(groups_np, minlength=n_groups)
    l_max = max(int(counts.max()), 1)
    members_np = np.full((n_groups, l_max), -1, np.int32)
    for g in range(n_groups):
        ids = np.nonzero(groups_np == g)[0]
        members_np[g, :len(ids)] = ids
    return jnp.asarray(members_np), jnp.asarray(counts.astype(np.float32))


def _resolve_config(*, backend, tile_n, min_cap, chunk, config, tune,
                    n, k, d):
    """Resolve the effective :class:`EngineConfig` for this fit.

    Precedence per knob: explicit ``fit`` kwarg > explicit ``config``
    object > tuned cache entry (``tune != "off"``) > built-in default.
    The caller's ``backend`` always wins unless it is ``"auto"``.
    Returns ``(config, resolved_backend)`` where the backend may be
    ``"lloyd"``.
    """
    cfg = DEFAULT_CONFIG
    if config is None and tune != "off":
        # "force" has already run the search by the time we get here
        # (fit() materialises it into an explicit config); both active
        # modes consult the persistent cache.
        from .. import tune as _tune
        cfg = _tune.lookup(n=n, k=k, d=d) or cfg
    if config is not None:
        cfg = config
    over = {}
    if tile_n is not None:
        over["tile_n"] = int(tile_n)
    if min_cap is not None:
        over["min_cap"] = int(min_cap)
    if chunk is not None:
        over["chunk"] = int(chunk)
    if over:
        cfg = cfg.replace(**over)

    resolved = backend
    if resolved == "auto":
        resolved = cfg.backend
    if resolved == "auto":
        if n * k <= cfg.lloyd_max_work:
            resolved = "lloyd"
        else:
            resolved = "pallas" if jax.default_backend() == "tpu" \
                else "compact"
    return cfg, resolved


def fit(points, init_centroids, *, n_groups: int | None = None,
        max_iters: int = 100, tol: float = 1e-4, backend: str = "auto",
        tile_n: int | None = None, min_cap: int | None = None,
        chunk: int | None = None, interpret: bool | None = None,
        max_bucket_switches: int = 32, return_stats: bool = False,
        config: EngineConfig | None = None, tune: str = "auto"):
    """Run filtered K-means fully device-resident.

    See the module docstring for backend semantics. ``interpret=None``
    auto-enables Pallas interpreter mode off-TPU, so
    ``backend='pallas'`` works (slowly) anywhere.

    ``config`` pins an explicit :class:`EngineConfig`; ``tune``
    controls the per-(platform, N, K, D) autotuning cache
    (:mod:`repro.tune`): ``"auto"`` (default) uses a cached winner when
    one exists, ``"force"`` additionally runs the measured search on a
    cache miss and persists the result, ``"off"`` uses built-in
    defaults. Tuning changes wall-clock only — assignments and inertia
    are bit-identical across configurations. Individual kwargs
    (``tile_n``/``min_cap``/``chunk``) override both.

    Returns a :class:`~repro.core.kmeans.KMeansResult`; with
    ``return_stats=True`` returns ``(result, EngineStats)``.
    """
    if backend not in BACKENDS + ("auto", "lloyd"):
        raise ValueError(f"unknown engine backend {backend!r}; "
                         f"expected one of "
                         f"{BACKENDS + ('auto', 'lloyd')}")
    if tune not in ("auto", "off", "force"):
        raise ValueError(f"unknown tune mode {tune!r}; expected "
                         f"'auto', 'off' or 'force'")
    points = jnp.asarray(points)
    init_c = jnp.asarray(init_centroids)
    if init_c.dtype != jnp.float32:
        init_c = init_c.astype(jnp.float32)
    k = init_c.shape[0]
    n, d = points.shape

    if tune == "force" and config is None:
        from .. import tune as _tune
        config = _tune.get_or_tune(
            points, init_c, n_groups=n_groups, max_iters=int(max_iters),
            tol=float(tol))
    cfg, backend = _resolve_config(
        backend=backend, tile_n=tile_n, min_cap=min_cap, chunk=chunk,
        config=config, tune=tune, n=n, k=k, d=d)

    if backend == "lloyd":
        res = _lloyd_jit(points, init_c, max_iters=int(max_iters),
                         tol=float(tol))
        if not return_stats:
            return res              # keep the tiny-problem route lean:
                                    # no stats blocking / dict building
        stats = EngineStats(backend="lloyd", n_iters=int(res.n_iters),
                            host_syncs=1, config=cfg.to_dict())
        return res, stats
    if interpret is None:
        interpret = backend == "pallas" and jax.default_backend() != "tpu"
    if n_groups is None:
        n_groups = max(k // 10, 1)
    n_groups = int(min(n_groups, k))
    tol = float(tol)

    stats = EngineStats(backend=backend, x2_evals=1, config=cfg.to_dict())
    cap_floor = min(cfg.min_cap, n)
    common_kw = dict(chunk=cfg.chunk, tile_n=cfg.tile_n,
                     group_gather_factor=cfg.group_gather_factor,
                     refresh_in_pass=cfg.refresh_in_pass,
                     interpret=bool(interpret))
    if n <= 4 * cap_floor:
        # small problem: eager setup + bucket churn costs more than the
        # whole fit — run the fully-fused single-program path
        ug = use_groups_decision(
            cap_n=n, cap_g=n_groups, l_max=k, k=k, chunk=cfg.chunk,
            group_gather_factor=cfg.group_gather_factor) \
            if backend == "compact" else None
        c, a, it, evals, inertia = _fit_fused(
            points, init_c, backend=backend, k=k, n_groups=n_groups,
            max_iters=int(max_iters), tol=tol, use_groups=ug, **common_kw)
        stats.host_syncs = 1
        stats.n_iters = int(it)
        if backend == "compact":
            stats.caps_history.append((n, n_groups))
            stats.use_groups.append(bool(ug))
        result = KMeansResult(c, a, it, evals, inertia)
        return (result, stats) if return_stats else result

    groups = group_centroids(init_c, n_groups)

    # group membership table (G, Lmax), -1-padded; one setup-time sync
    groups_np = np.asarray(jax.device_get(groups))
    stats.host_syncs += 1
    members, gsize = build_group_tables(groups_np, n_groups)
    l_max = int(members.shape[1])

    carry = _init_carry(points, init_c, groups, n_groups=n_groups)

    # start tiny: the first loop body's pending candidate pass is empty
    # (carry.need = 0), so a full-capacity program would burn one whole
    # dense pass on padding. The first real candidate count exits the
    # loop after iteration 1 and picks the right bucket.
    cap_n, cap_g = cap_floor, 1
    loop_kw = dict(backend=backend, k=k, n_groups=n_groups,
                   max_iters=int(max_iters), tol=tol, min_cap=cap_floor,
                   down_n=cfg.down_n, down_g=cfg.down_g, **common_kw)

    def _ug(cn, cg):
        if backend != "compact":
            return None
        return use_groups_decision(
            cap_n=cn, cap_g=cg, l_max=l_max, k=k, chunk=cfg.chunk,
            group_gather_factor=cfg.group_gather_factor)

    while True:
        ug = _ug(cap_n, cap_g)
        stats.caps_history.append((cap_n, cap_g))
        if backend == "compact":
            stats.use_groups.append(bool(ug))
        allow_down = stats.bucket_switches < max_bucket_switches
        carry = _run_loop(points, carry, groups, members, gsize,
                          cap_n=cap_n, cap_g=cap_g,
                          allow_downshift=allow_down, use_groups=ug,
                          **loop_kw)
        it, nc, gm, sh = jax.device_get(
            (carry.iteration, carry.n_cand, carry.gmax, carry.shift))
        stats.host_syncs += 1
        if int(it) >= max_iters or float(sh) <= tol:
            break
        if backend != "compact":          # single-trace backends never
            break                         # exit the loop non-terminally
        stats.bucket_switches += 1
        if stats.bucket_switches >= max_bucket_switches:
            cap_n, cap_g = _bucket_cap(n, cap_floor, n), n_groups
        else:
            cap_n = _bucket_cap(int(nc), cap_floor, n)
            # gmax == 0 means no candidate pass has run at this bucket
            # yet (the opening probe segment): guess the full group
            # count rather than burning a whole segment discovering it
            cap_g = _bucket_cap(int(gm), 1, n_groups) if int(gm) > 0 \
                else n_groups
    stats.n_iters = int(it)

    # epilogue: the final iteration's pending candidate pass + inertia.
    # Caps only key the compact pass; pin them for the single-trace
    # backends so the epilogue compiles exactly once.
    if backend == "compact":
        ecap_n = _bucket_cap(int(nc), cap_floor, n)
        ecap_g = _bucket_cap(int(gm), 1, n_groups)
    else:
        ecap_n, ecap_g = n, n_groups
    assignments, evals, inertia = _epilogue(
        points, carry, groups, members, gsize, backend=backend,
        n_groups=n_groups, cap_n=ecap_n, cap_g=ecap_g,
        use_groups=_ug(ecap_n, ecap_g), **common_kw)

    result = KMeansResult(carry.centroids, assignments, carry.iteration,
                          evals, inertia)
    if return_stats:
        return result, stats
    return result


# --------------------------------------------------------------------------
# streaming / mini-batch single-pass step (driven by repro.streaming)
# --------------------------------------------------------------------------

class StreamStepOut(NamedTuple):
    """Outputs of one mini-batch :func:`stream_update` step. The
    returned ``ub``/``lb`` are already decayed by this step's centroid
    drift, i.e. valid against the RETURNED centroids — exactly what the
    caller's per-shard bound cache wants to store."""
    centroids: jnp.ndarray    # (K, D) after the decayed update
    counts: jnp.ndarray       # (K,) decayed effective counts
    assignments: jnp.ndarray  # (B,)
    ub: jnp.ndarray           # (B,) post-move upper bounds
    lb: jnp.ndarray           # (B, G) post-move lower bounds
    pairs: jnp.ndarray        # f32: point-centroid pairs scored
    gmax: jnp.ndarray         # int32: surviving-group high-water
    drift: jnp.ndarray        # (K,) this step's per-centroid drift
    gdrift: jnp.ndarray       # (G,) this step's per-group max drift
    batch_counts: jnp.ndarray  # (K,) points of THIS batch per centroid
    batch_cost: jnp.ndarray   # f32 sum(ub^2) pre-move: an upper-bound
                              # estimate of the batch's inertia


@jax.jit
def stream_bounds(points, centroids, assignments, ub, lb):
    """Point-level filter over CARRIED (drift-inflated) bounds — the
    first half of ``move_and_bounds`` without the centroid move. ``ub``
    must upper-bound d(x, centroids[assignments]) and ``lb`` must
    lower-bound the per-group min excluding the assignment (the shard
    cache's :func:`repro.streaming.inflate_bounds` contract).

    Returns ``(ub_t, need, n_cand, n_tightened)``: tightened upper
    bounds, the pending candidate mask, its popcount, and how many
    exact own-centroid distances were spent tightening.
    """
    glb = jnp.min(lb, axis=1)
    maybe = ub > glb
    d_own = rowwise_dists(points, centroids[assignments])
    ub_t = jnp.where(maybe, d_own, ub)
    need = ub_t > glb
    return ub_t, need, jnp.sum(need.astype(jnp.int32)), jnp.sum(
        maybe.astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=(
    "k", "n_groups", "cap_n", "cap_g", "chunk", "group_gather_factor"))
def stream_update(points, centroids, counts, decay, groups, members, gsize,
                  assignments, ub_t, lb, need, *, k, n_groups, cap_n,
                  cap_g, chunk=2048, group_gather_factor=4):
    """One mini-batch against EXTERNAL carry (centroids + effective
    counts): the engine's two-level compacted candidate pass, then a
    decayed count-weighted centroid update, then post-move bound decay.

    This is the reusable single-pass step behind
    :class:`repro.streaming.StreamingKMeans`. The update is the
    mini-batch EMA ``c <- (decay * n_c * c + sum_batch) / (decay * n_c
    + b_c)``: ``decay=1`` is pure count-weighting (per-centroid 1/n
    learning rate), ``decay<1`` caps the memory at ~1/(1-decay)
    batches. ``cap_n`` MUST be >= the candidate count (the caller syncs
    it via :func:`stream_bounds`); ``cap_g`` is a guess — the pass's
    ``lax.cond`` spills to the dense branch when it is exceeded, and
    the returned ``gmax`` recalibrates the next visit.
    ``group_gather_factor`` / ``chunk`` come from the tuned
    :class:`EngineConfig` when the caller enables tuning.
    """
    x2 = row_norms_sq(points)                 # once per batch
    c2 = row_norms_sq(centroids)
    new_as, nub, nlb, pairs, gmax = compact_candidate_pass(
        points, centroids, assignments, ub_t, lb, groups, members, gsize,
        need, cap_n=cap_n, cap_g=cap_g, n_groups=n_groups, chunk=chunk,
        opt_sq=True, x2=x2, c2=c2, group_gather_factor=group_gather_factor)
    bsums, bcounts = centroid_sums(points, new_as, k)
    return stream_ema_and_decay(centroids, counts, decay, bsums, bcounts,
                                new_as, nub, nlb, pairs, gmax, groups,
                                n_groups=n_groups)


def stream_ema_and_decay(centroids, counts, decay, bsums, bcounts, new_as,
                         nub, nlb, pairs, gmax, groups, *, n_groups: int):
    """The streaming step's epilogue — decayed count-weighted centroid
    EMA, this step's drift, post-move bound decay — shared by the local
    :func:`stream_update` and the sharded step
    (``repro.core.distributed.make_stream_update_sharded``, which
    psums ``bsums``/``bcounts`` before calling and reduces the scalar
    outputs after). THE single copy of the update rule."""
    dec = counts * decay
    new_counts = dec + bcounts
    sums = dec[:, None] * centroids + bsums
    # fractional decayed counts: guard with an epsilon, not the batch
    # fit's max(counts, 1) (which assumes integer counts)
    new_c = jnp.where(new_counts[:, None] > 1e-6,
                      sums / jnp.maximum(new_counts, 1e-6)[:, None],
                      centroids)

    drift = jnp.linalg.norm(new_c - centroids, axis=-1)
    # clamp: segment_max of an EMPTY group is -inf, which the batch
    # loop tolerates but would poison the caller's cumulative drift
    # ledger (inf - inf = NaN on the next inflation)
    gdrift = jnp.maximum(
        jax.ops.segment_max(drift, groups, num_segments=n_groups), 0.0)
    # sentinel-padded rows (sharded caller) carry assignment K: the
    # traced gather clamps, and the caller slices their ub/lb off
    out_ub = nub + drift[new_as]
    out_lb = jnp.maximum(nlb - gdrift[None, :], 0.0)
    return StreamStepOut(new_c, new_counts, new_as, out_ub, out_lb,
                         pairs, gmax, drift, gdrift, bcounts,
                         jnp.sum(nub * nub))
