"""Device-resident filtered K-means execution engine.

This is the single executor behind the KPynq filter family, replacing
the three divergent drivers (masked-dense oracle, host-synced compact
driver, ad-hoc kernel glue) with one iteration loop that realises BOTH
filter levels as skipped work:

* the whole fit runs under ``lax.while_loop`` — zero host round-trips
  per iteration. The only host syncs are capacity-bucket transitions
  (O(log N) of them, counted in :class:`EngineStats`), not one per
  iteration like the legacy ``yinyang_compact`` driver;
* **point-level compaction**: surviving points are stream-compacted
  into a padded buffer whose capacity comes from a fixed power-of-two
  lattice, so XLA compiles a small, bounded set of programs;
* **centroid-level compaction**: each candidate's *surviving groups*
  are compacted into a padded per-point group bucket and only those
  groups' centroids are gathered for the distance pass — the
  group-level filter becomes skipped FLOPs, not just bookkeeping;
* the Pallas block-skip kernel (``repro.kernels.grouped_assign``) slots
  in as the TPU backend behind the same interface.

Backend selection (``backend=`` on :func:`fit`):

``"oracle"``
    Masked-dense pass over all N points every iteration — computes every
    distance and discards the filtered ones. Ground truth / debugging.
``"compact"``
    The two-level compaction path above. Default off-TPU: on CPU/GPU
    this is what turns filter rates into wall-clock speedup.
``"pallas"``
    Group-granular block-skip Pallas kernel (``interpret=True`` runs it
    anywhere). Default on TPU, where per-point gathers are hostile but
    skipping whole (tile_n x group) blocks is free.
``"auto"``
    ``"pallas"`` when ``jax.default_backend() == "tpu"``, else
    ``"compact"`` — EXCEPT tiny problems (``n * k <=
    AUTO_LLOYD_MAX_WORK``), which route straight to the reference
    Lloyd loop: below that size one dense GEMM per iteration beats any
    filter bookkeeping (measured in ``BENCH_kmeans.json``, uci-small).

Every backend is exact: fixed points are identical to Lloyd's
(``tests/test_engine.py`` checks assignments/inertia parity across the
whole matrix). The split-loop construction (candidate pass for
iteration *i* runs at the top of body *i+1*, with a single epilogue
pass after the loop) is what lets the bucket conditions live in the
``while_loop`` *cond* without ever re-doing or skipping work.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .distances import pairwise_dists, pairwise_sq_dists, rowwise_dists
from .kmeans import (EvalCount, KMeansResult, _init_filter_state,
                     centroid_sums, centroids_from_sums, group_centroids,
                     lloyd)

BACKENDS = ("oracle", "compact", "pallas")

# backend="auto" routes problems with n*k at or below this straight to
# the reference Lloyd loop: BENCH_kmeans.json shows the dense (N, K)
# GEMM beating the filtered engine by ~3.6x at uci-small scale (n=512,
# k=32 -> n*k=16384) — at that size one fused matmul per iteration is
# cheaper than any bound bookkeeping. The fixed point is identical
# (tests/test_engine.py parity matrix), only distance_evals differ.
AUTO_LLOYD_MAX_WORK = 1 << 17

# jit-cached Lloyd for the tiny-problem route: calling the bare
# function would re-trace its while_loop on every fit, costing more
# than the fit itself at these sizes
_lloyd_jit = functools.partial(jax.jit, static_argnames=(
    "max_iters", "tol"))(lambda points, init_c, *, max_iters, tol:
                         lloyd(points, init_c, max_iters, tol))


# --------------------------------------------------------------------------
# shared per-iteration pieces (also consumed by compact.py / distributed.py)
# --------------------------------------------------------------------------

def move_and_bounds(points, centroids, assignments, ub, lb, groups,
                    *, k: int, n_groups: int, reduce_sums=None):
    """Centroid move + triangle-inequality bound maintenance + the
    point-level filter. Pure traced function shared by every driver.

    ``reduce_sums``: optional ``(sums, counts) -> (sums, counts)`` hook
    applied to the per-shard centroid partial sums (``lax.psum`` in the
    distributed fit; identity locally).

    Returns ``(new_c, ub_t, lb_dec, need, shift, n_tightened)`` where
    ``need`` marks points that must enter the candidate distance pass.
    """
    sums, counts = centroid_sums(points, assignments, k)
    if reduce_sums is not None:
        sums, counts = reduce_sums(sums, counts)
    new_c = centroids_from_sums(sums, counts, centroids)

    drift = jnp.linalg.norm(new_c - centroids, axis=-1)
    group_drift = jax.ops.segment_max(drift, groups, num_segments=n_groups)
    shift = jnp.max(drift)
    ub = ub + drift[assignments]
    lb_dec = jnp.maximum(lb - group_drift[None, :], 0.0)
    glb = jnp.min(lb_dec, axis=1)
    maybe = ub > glb
    d_own = rowwise_dists(points, new_c[assignments])
    ub_t = jnp.where(maybe, d_own, ub)
    need = ub_t > glb
    return new_c, ub_t, lb_dec, need, shift, jnp.sum(
        maybe.astype(jnp.float32))


def dense_candidate_pass(points, new_c, assignments, ub_t, lb, groups, need,
                         *, n_groups: int, opt_sq: bool = False):
    """Masked-dense candidate pass over all N points (oracle backend and
    the per-shard distributed step). Group filter applied as a mask —
    exact semantics, no skipped FLOPs.

    ``opt_sq=True`` runs min/argmin on SQUARED distances and sqrts only
    the reduced outputs (monotone => bit-identical results, one fewer
    (N, K) sqrt pass + HBM round-trip).

    Returns ``(new_assign, new_ub, new_lb, n_pairs)``.
    """
    n = points.shape[0]
    rows = jnp.arange(n)
    group_need = need[:, None] & (lb < ub_t[:, None])              # (N, G)
    cand = group_need[:, groups]                                    # (N, K)
    pairs = jnp.sum(cand.astype(jnp.float32))

    if opt_sq:
        d_cand = jnp.where(cand, pairwise_sq_dists(points, new_c), jnp.inf)
        best = jnp.argmin(d_cand, axis=1).astype(jnp.int32)
        best_d = jnp.sqrt(jnp.min(d_cand, axis=1))
    else:
        d_cand = jnp.where(cand, pairwise_dists(points, new_c), jnp.inf)
        best = jnp.argmin(d_cand, axis=1).astype(jnp.int32)
        best_d = jnp.min(d_cand, axis=1)
    changed = best_d < ub_t
    new_assign = jnp.where(changed, best, assignments)
    new_ub = jnp.minimum(ub_t, best_d)

    d_excl = d_cand.at[rows, new_assign].set(jnp.inf)
    lb_comp = jax.ops.segment_min(d_excl.T, groups,
                                  num_segments=n_groups).T          # (N, G)
    if opt_sq:
        lb_comp = jnp.sqrt(lb_comp)
    new_lb = jnp.where(group_need, lb_comp, lb)
    old_group = groups[assignments]
    new_lb = new_lb.at[rows, old_group].min(
        jnp.where(changed, ub_t, jnp.inf))
    return new_assign, new_ub, new_lb, pairs


def compact_candidate_pass(points, new_c, assignments, ub_t, lb, groups,
                           members, gsize, need, *, cap_n: int, cap_g: int,
                           n_groups: int, chunk: int = 2048,
                           use_groups: bool | None = None,
                           opt_sq: bool = False):
    """Two-level compacted candidate pass.

    Point level: the ``need`` survivors are stream-compacted into a
    ``cap_n`` buffer (``cap_n`` must be >= the survivor count — the
    engine's while-loop cond guarantees it).

    Centroid level: each candidate's surviving groups are compacted
    into a ``cap_g``-slot bucket; only those groups' member centroids
    (``members``: (G, Lmax) int32, -1-padded) are gathered and scored.
    When ``cap_g * Lmax`` is not meaningfully smaller than K the pass
    statically falls back to one dense (cap_n, K) matmul — a BLAS GEMM
    beats per-point gathers unless the group filter removes >= ~4x.
    When the bucket IS compiled in, a runtime ``lax.cond`` spills to the
    dense branch whenever some candidate's surviving-group count
    exceeds ``cap_g`` — exactness never depends on the bucket guess;
    the engine reads the returned ``gmax`` to upshift the next segment.

    Returns updated full-size ``(assignments, ub, lb, n_pairs, gmax)``.
    """
    n = points.shape[0]
    k = new_c.shape[0]
    l_max = members.shape[1]
    rows = jnp.arange(cap_n)

    # --- point-level compaction -------------------------------------
    pos = jnp.cumsum(need.astype(jnp.int32)) - 1
    slot = jnp.where(need, pos, cap_n)
    idx = jnp.zeros((cap_n,), jnp.int32).at[slot].set(
        jnp.arange(n, dtype=jnp.int32), mode="drop")
    count = jnp.sum(need.astype(jnp.int32))
    valid = jnp.arange(cap_n) < count

    cpts = points[idx]                                        # (cap, D)
    c_ub = ub_t[idx]
    c_lb = lb[idx]                                            # (cap, G)
    c_as = assignments[idx]
    gneed = (c_lb < c_ub[:, None]) & valid[:, None]           # (cap, G)
    gmax = jnp.max(jnp.sum(gneed.astype(jnp.int32), axis=1))

    if use_groups is None:
        # auto: bucket only when the group filter removes >= ~4x of K
        # AND the candidate set is small — XLA per-point gathers beat
        # the dense GEMM only below ~one chunk of survivors (measured
        # on CPU; the TPU realisation is the pallas backend instead)
        use_groups = (cap_g * l_max * 4 <= k) and cap_n <= chunk

    def dense_branch(_):
        # one (cap_n, K) GEMM on the survivors
        gmask = gneed[:, groups]                              # (cap, K)
        if opt_sq:
            # min/argmin on squared distances (monotone => identical),
            # sqrt only the (cap,)/(cap, G) reductions: one fewer
            # (cap, K) sqrt pass per iteration.
            d_cand = jnp.where(gmask, pairwise_sq_dists(cpts, new_c),
                               jnp.inf)
            bid = jnp.argmin(d_cand, axis=1).astype(jnp.int32)
            bd = jnp.sqrt(jnp.min(d_cand, axis=1))
        else:
            d_cand = jnp.where(gmask, pairwise_dists(cpts, new_c), jnp.inf)
            bid = jnp.argmin(d_cand, axis=1).astype(jnp.int32)
            bd = jnp.min(d_cand, axis=1)
        chg = bd < c_ub
        nas = jnp.where(chg, bid, c_as)
        nub = jnp.minimum(c_ub, bd)
        d_excl = d_cand.at[rows, nas].set(jnp.inf)
        lb_comp = jax.ops.segment_min(d_excl.T, groups,
                                      num_segments=n_groups).T
        if opt_sq:
            lb_comp = jnp.sqrt(lb_comp)
        new_clb = jnp.where(gneed, lb_comp, c_lb)
        pairs = count.astype(jnp.float32) * k
        return nas, nub, new_clb, pairs, chg

    def group_branch(_):
        # centroid-level compaction: padded per-point group bucket
        gpos = jnp.cumsum(gneed.astype(jnp.int32), axis=1) - 1
        gslot = jnp.where(gneed, gpos, cap_g)
        gsel = jnp.full((cap_n, cap_g), n_groups, jnp.int32).at[
            rows[:, None], gslot].set(
            jnp.broadcast_to(jnp.arange(n_groups, dtype=jnp.int32),
                             (cap_n, n_groups)), mode="drop")
        c2 = jnp.sum(new_c.astype(jnp.float32) ** 2, axis=-1)  # (K,)

        def bucket_pass(x, gs, cub, cas):
            mem = jnp.take(members, gs, axis=0, mode="fill",
                           fill_value=-1)                # (ch, cap_g, L)
            mem_s = jnp.maximum(mem, 0)
            csel = new_c[mem_s]                          # (ch, cap_g, L, D)
            xf = x.astype(jnp.float32)
            x2 = jnp.sum(xf * xf, axis=-1)[:, None, None]
            cross = jnp.einsum("nd,ngld->ngl", xf,
                               csel.astype(jnp.float32))
            d2 = jnp.maximum(x2 - 2.0 * cross + c2[mem_s], 0.0)
            ch = x.shape[0]
            # squared-distance reductions, sqrt only the outputs
            dm = jnp.where(mem >= 0, d2, jnp.inf).reshape(ch, -1)
            memf = mem.reshape(ch, -1)
            bcol = jnp.argmin(dm, axis=1)
            bd = jnp.sqrt(jnp.min(dm, axis=1))
            bid = jnp.take_along_axis(memf, bcol[:, None], 1)[:, 0]
            chg = bd < cub
            nas = jnp.where(chg, bid, cas).astype(jnp.int32)
            nub = jnp.minimum(cub, bd)
            d_ex = jnp.where(memf == nas[:, None], jnp.inf, dm)
            smin = jnp.sqrt(jnp.min(d_ex.reshape(ch, cap_g, l_max),
                                    axis=2))
            return nas, nub, smin, chg

        nas, nub, smin, chg = bucket_pass(cpts, gsel, c_ub, c_as)
        new_clb = c_lb.at[rows[:, None], gsel].set(smin, mode="drop")
        pairs = jnp.sum(gneed.astype(jnp.float32) * gsize[None, :])
        return nas, nub, new_clb, pairs, chg

    if use_groups:
        nas, nub, new_clb, pairs, chg = jax.lax.cond(
            gmax <= cap_g, group_branch, dense_branch, operand=None)
    else:
        nas, nub, new_clb, pairs, chg = dense_branch(None)

    old_group = jnp.take(groups, c_as)                        # (cap,)
    new_clb = new_clb.at[rows, old_group].min(
        jnp.where(chg, c_ub, jnp.inf))

    # --- scatter survivors back (invalid slots dropped) --------------
    sidx = jnp.where(valid, idx, n)
    assignments = assignments.at[sidx].set(nas, mode="drop")
    ub_out = ub_t.at[sidx].set(nub, mode="drop")
    lb_out = lb.at[sidx].set(new_clb, mode="drop")
    return assignments, ub_out, lb_out, pairs, gmax


def pallas_candidate_pass(points, new_c, assignments, ub_t, lb, groups,
                          members, gsize, need, *, n_groups: int,
                          tile_n: int = 256, interpret: bool = False):
    """Candidate pass through the grouped block-skip Pallas kernel.

    The (point, group) filter decisions become a (N/tile_n, G) block
    mask; the kernel runs the distance matmul only for live blocks and
    returns the global (min, argmin) plus per-group (min, argmin,
    second-min) — exactly what the Yinyang lower-bound refresh needs,
    with no (N, K) distance matrix ever materialised.
    """
    from ..kernels import build_group_block_mask, grouped_assign

    n = points.shape[0]
    rows = jnp.arange(n)
    group_need = need[:, None] & (lb < ub_t[:, None])              # (N, G)
    mask = build_group_block_mask(group_need, tile_n=tile_n)       # (gn, G)
    c_grouped = new_c[jnp.maximum(members, 0)]              # (G, Lmax, D)
    best2, idx, gmin, garg, gmin2 = grouped_assign(
        points, c_grouped, members, mask, tile_n=tile_n,
        interpret=interpret)

    best_d = jnp.sqrt(best2)
    changed = best_d < ub_t
    new_assign = jnp.where(changed, idx, assignments)
    new_ub = jnp.minimum(ub_t, best_d)

    # per-group min excluding the (new) assigned centroid: the group
    # argmin collides with the assignment iff the assignment came from
    # that group, in which case the second-min is the excluded min.
    lb_comp = jnp.sqrt(jnp.where(garg == new_assign[:, None], gmin2, gmin))
    new_lb = jnp.where(group_need, lb_comp, lb)
    old_group = groups[assignments]
    new_lb = new_lb.at[rows, old_group].min(
        jnp.where(changed, ub_t, jnp.inf))
    pairs = jnp.float32(tile_n) * jnp.sum(
        mask.astype(jnp.float32) * gsize[None, :])
    return new_assign, new_ub, new_lb, pairs


# --------------------------------------------------------------------------
# the device-resident loop
# --------------------------------------------------------------------------

class EngineCarry(NamedTuple):
    """while_loop carry. ``ub``/``lb``/``need`` describe the PENDING
    candidate pass (iteration ``iteration``'s second half), which the
    next loop body — or the epilogue — executes."""
    iteration: jnp.ndarray    # int32: completed move+bounds iterations
    centroids: jnp.ndarray    # (K, D)
    assignments: jnp.ndarray  # (N,)
    ub: jnp.ndarray           # (N,) tightened upper bounds
    lb: jnp.ndarray           # (N, G) decayed lower bounds
    need: jnp.ndarray         # (N,) pending candidate mask
    n_cand: jnp.ndarray       # int32 = sum(need)
    gmax: jnp.ndarray         # int32 max surviving groups per candidate,
                              # as observed by the LAST executed pass
    shift: jnp.ndarray        # f32 max centroid drift
    evals: EvalCount


@dataclasses.dataclass
class EngineStats:
    """Execution telemetry: the 'no per-iteration host sync' claim is
    checkable as ``host_syncs << n_iters``."""
    backend: str = ""
    n_iters: int = 0
    host_syncs: int = 0
    bucket_switches: int = 0
    caps_history: list = dataclasses.field(default_factory=list)


def _candidate_pass(backend, points, carry, groups, members, gsize, *,
                    n_groups, cap_n, cap_g, chunk, tile_n, interpret):
    """Backend dispatch, normalised to (assign, ub, lb, pairs, gmax)."""
    if backend == "oracle":
        out = dense_candidate_pass(
            points, carry.centroids, carry.assignments, carry.ub, carry.lb,
            groups, carry.need, n_groups=n_groups)
        return out + (jnp.int32(0),)
    if backend == "pallas":
        out = pallas_candidate_pass(
            points, carry.centroids, carry.assignments, carry.ub, carry.lb,
            groups, members, gsize, carry.need, n_groups=n_groups,
            tile_n=tile_n, interpret=interpret)
        return out + (jnp.int32(0),)
    return compact_candidate_pass(
        points, carry.centroids, carry.assignments, carry.ub, carry.lb,
        groups, members, gsize, carry.need, cap_n=cap_n, cap_g=cap_g,
        n_groups=n_groups, chunk=chunk, opt_sq=True)


@functools.partial(jax.jit, static_argnames=(
    "backend", "k", "n_groups", "cap_n", "cap_g", "max_iters", "tol",
    "min_cap", "allow_downshift", "chunk", "tile_n", "interpret"))
def _run_loop(points, carry, groups, members, gsize, *, backend, k,
              n_groups, cap_n, cap_g, max_iters, tol, min_cap,
              allow_downshift, chunk, tile_n, interpret):
    """One capacity bucket's worth of device-resident iterations.

    Exits when converged / out of iterations (terminal), or — compact
    backend only — when the pending candidate count leaves its bucket
    ((cap/2, cap] for points, (cap/4, cap] for group slots), at which
    point the host picks the next bucket from the exit scalars. That
    is the ONLY host sync."""

    def cond(c):
        active = jnp.logical_and(c.iteration < max_iters, c.shift > tol)
        if backend != "compact":
            return active
        fits = jnp.logical_and(c.n_cand <= cap_n, c.gmax <= cap_g)
        ok = jnp.logical_and(active, fits)
        if allow_downshift:
            # exit when a strictly smaller point bucket would fit — the
            # candidate pass is linear in cap_n, so one sync (~ms) buys
            # back every decay-phase iteration's padding. The group cap
            # only affects the bucketed pass's minor axis; chase it
            # lazily (4x) to avoid segment churn.
            down = jnp.logical_or(
                jnp.logical_and(c.n_cand * 2 <= cap_n, cap_n > min_cap),
                jnp.logical_and(c.gmax * 4 <= cap_g, cap_g > 1))
            ok = jnp.logical_and(ok, jnp.logical_not(down))
        return ok

    def body(c):
        new_as, new_ub, new_lb, pairs, gmax = _candidate_pass(
            backend, points, c, groups, members, gsize, n_groups=n_groups,
            cap_n=cap_n, cap_g=cap_g, chunk=chunk, tile_n=tile_n,
            interpret=interpret)
        new_c, ub_t, lb_dec, need, shift, tightened = move_and_bounds(
            points, c.centroids, new_as, new_ub, new_lb, groups,
            k=k, n_groups=n_groups)
        n_cand = jnp.sum(need.astype(jnp.int32))
        return EngineCarry(c.iteration + 1, new_c, new_as, ub_t, lb_dec,
                           need, n_cand, gmax, shift,
                           c.evals.add(pairs).add(tightened))

    return jax.lax.while_loop(cond, body, carry)


@functools.partial(jax.jit, static_argnames=(
    "backend", "n_groups", "cap_n", "cap_g", "chunk", "tile_n",
    "interpret"))
def _epilogue(points, carry, groups, members, gsize, *, backend, n_groups,
              cap_n, cap_g, chunk, tile_n, interpret):
    """Final pending candidate pass + inertia, fused into one program."""
    new_as, _, _, pairs, _ = _candidate_pass(
        backend, points, carry, groups, members, gsize, n_groups=n_groups,
        cap_n=cap_n, cap_g=cap_g, chunk=chunk, tile_n=tile_n,
        interpret=interpret)
    evals = carry.evals.add(pairs)
    d = rowwise_dists(points, carry.centroids[new_as])
    return new_as, evals.total(), jnp.sum(d * d)


@functools.partial(jax.jit, static_argnames=(
    "backend", "k", "n_groups", "max_iters", "tol", "chunk", "tile_n",
    "interpret"))
def _fit_fused(points, init_c, *, backend, k, n_groups, max_iters, tol,
               chunk, tile_n, interpret):
    """Whole fit — grouping, init, loop, epilogue — as ONE program.

    Used for small problems (and exercised by tests for every backend):
    at a few thousand points the ~10 eager setup dispatches of the
    bucketed driver cost more than the entire fit, so run a single
    full-capacity segment with the group-membership table built on
    device (Lmax = K upper bound; fine at small K). Reuses _run_loop /
    _epilogue — at full capacities their bucket conditions are
    vacuous, so nesting them in this jit inlines to one program."""
    n = points.shape[0]
    groups = group_centroids(init_c, n_groups)
    # device-side (G, K) membership table: row g lists group g's
    # centroids in ascending order, -1-padded
    order = jnp.argsort(groups, stable=True)
    sg = groups[order]
    starts = jnp.searchsorted(sg, jnp.arange(n_groups))
    rank = jnp.arange(k) - starts[sg]
    members = jnp.full((n_groups, k), -1, jnp.int32).at[
        sg, rank].set(order.astype(jnp.int32))
    gsize = jax.ops.segment_sum(jnp.ones((k,), jnp.float32), groups,
                                num_segments=n_groups)

    state0 = _init_filter_state(points, init_c, groups, n_groups)
    carry = EngineCarry(
        jnp.int32(0), state0.centroids, state0.assignments, state0.ub,
        state0.lb, jnp.zeros((n,), bool), jnp.int32(0), jnp.int32(0),
        jnp.float32(jnp.inf), state0.distance_evals)

    carry = _run_loop(points, carry, groups, members, gsize,
                      backend=backend, k=k, n_groups=n_groups, cap_n=n,
                      cap_g=n_groups, max_iters=max_iters, tol=tol,
                      min_cap=n, allow_downshift=False, chunk=chunk,
                      tile_n=tile_n, interpret=interpret)
    new_as, evals, inertia = _epilogue(
        points, carry, groups, members, gsize, backend=backend,
        n_groups=n_groups, cap_n=n, cap_g=n_groups, chunk=chunk,
        tile_n=tile_n, interpret=interpret)
    return carry.centroids, new_as, carry.iteration, evals, inertia


def _bucket_cap(count: int, floor: int, ceil: int) -> int:
    """Smallest power-of-two >= count, clamped to [floor, ceil]. The
    lattice keeps the set of compiled programs small and reusable."""
    cap = 1 << (max(int(count), 1) - 1).bit_length()
    return max(min(cap, ceil), min(floor, ceil))


def build_group_tables(groups_np: np.ndarray, n_groups: int):
    """Host-side group tables: (G, Lmax) -1-padded membership matrix +
    fp32 group sizes. Shared by the batch fit and the streaming step."""
    counts = np.bincount(groups_np, minlength=n_groups)
    l_max = max(int(counts.max()), 1)
    members_np = np.full((n_groups, l_max), -1, np.int32)
    for g in range(n_groups):
        ids = np.nonzero(groups_np == g)[0]
        members_np[g, :len(ids)] = ids
    return jnp.asarray(members_np), jnp.asarray(counts.astype(np.float32))


def fit(points, init_centroids, *, n_groups: int | None = None,
        max_iters: int = 100, tol: float = 1e-4, backend: str = "auto",
        tile_n: int = 256, min_cap: int = 256, chunk: int = 2048,
        interpret: bool | None = None, max_bucket_switches: int = 32,
        return_stats: bool = False):
    """Run filtered K-means fully device-resident.

    See the module docstring for backend semantics. ``interpret=None``
    auto-enables Pallas interpreter mode off-TPU, so
    ``backend='pallas'`` works (slowly) anywhere. Returns a
    :class:`~repro.core.kmeans.KMeansResult`; with
    ``return_stats=True`` returns ``(result, EngineStats)``.
    """
    if backend not in BACKENDS + ("auto",):
        raise ValueError(f"unknown engine backend {backend!r}; "
                         f"expected one of {BACKENDS + ('auto',)}")
    points = jnp.asarray(points)
    init_c = jnp.asarray(init_centroids, jnp.float32)
    k = init_c.shape[0]
    n = points.shape[0]
    if backend == "auto":
        if n * k <= AUTO_LLOYD_MAX_WORK:
            res = _lloyd_jit(points, init_c, max_iters=int(max_iters),
                             tol=float(tol))
            stats = EngineStats(backend="lloyd", n_iters=int(res.n_iters),
                                host_syncs=1)
            return (res, stats) if return_stats else res
        backend = "pallas" if jax.default_backend() == "tpu" else "compact"
    if interpret is None:
        interpret = backend == "pallas" and jax.default_backend() != "tpu"
    if n_groups is None:
        n_groups = max(k // 10, 1)
    n_groups = int(min(n_groups, k))
    tol = float(tol)

    stats = EngineStats(backend=backend)
    cap_floor = min(min_cap, n)
    if n <= 4 * cap_floor:
        # small problem: eager setup + bucket churn costs more than the
        # whole fit — run the fully-fused single-program path
        c, a, it, evals, inertia = _fit_fused(
            points, init_c, backend=backend, k=k, n_groups=n_groups,
            max_iters=int(max_iters), tol=tol, chunk=int(chunk),
            tile_n=int(tile_n), interpret=bool(interpret))
        stats.host_syncs = 1
        stats.n_iters = int(it)
        result = KMeansResult(c, a, it, evals, inertia)
        return (result, stats) if return_stats else result

    groups = group_centroids(init_c, n_groups)

    # group membership table (G, Lmax), -1-padded; one setup-time sync
    groups_np = np.asarray(jax.device_get(groups))
    stats.host_syncs += 1
    members, gsize = build_group_tables(groups_np, n_groups)

    state0 = _init_filter_state(points, init_c, groups, n_groups)
    carry = EngineCarry(
        jnp.int32(0), state0.centroids, state0.assignments, state0.ub,
        state0.lb, jnp.zeros((n,), bool), jnp.int32(0), jnp.int32(0),
        jnp.float32(jnp.inf), state0.distance_evals)

    # start tiny: the first loop body's pending candidate pass is empty
    # (carry.need = 0), so a full-capacity program would burn one whole
    # dense pass on padding. The first real candidate count exits the
    # loop after iteration 1 and picks the right bucket.
    cap_n, cap_g = cap_floor, 1
    loop_kw = dict(backend=backend, k=k, n_groups=n_groups,
                   max_iters=int(max_iters), tol=tol, min_cap=cap_floor,
                   chunk=int(chunk), tile_n=int(tile_n),
                   interpret=bool(interpret))

    while True:
        stats.caps_history.append((cap_n, cap_g))
        allow_down = stats.bucket_switches < max_bucket_switches
        carry = _run_loop(points, carry, groups, members, gsize,
                          cap_n=cap_n, cap_g=cap_g,
                          allow_downshift=allow_down, **loop_kw)
        it, nc, gm, sh = jax.device_get(
            (carry.iteration, carry.n_cand, carry.gmax, carry.shift))
        stats.host_syncs += 1
        if int(it) >= max_iters or float(sh) <= tol:
            break
        if backend != "compact":          # single-trace backends never
            break                         # exit the loop non-terminally
        stats.bucket_switches += 1
        if stats.bucket_switches >= max_bucket_switches:
            cap_n, cap_g = _bucket_cap(n, cap_floor, n), n_groups
        else:
            cap_n = _bucket_cap(int(nc), cap_floor, n)
            cap_g = _bucket_cap(int(gm), 1, n_groups)
    stats.n_iters = int(it)

    # epilogue: the final iteration's pending candidate pass + inertia.
    # Caps only key the compact pass; pin them for the single-trace
    # backends so the epilogue compiles exactly once.
    if backend == "compact":
        ecap_n = _bucket_cap(int(nc), cap_floor, n)
        ecap_g = _bucket_cap(int(gm), 1, n_groups)
    else:
        ecap_n, ecap_g = n, n_groups
    assignments, evals, inertia = _epilogue(
        points, carry, groups, members, gsize, backend=backend,
        n_groups=n_groups, cap_n=ecap_n, cap_g=ecap_g, chunk=int(chunk),
        tile_n=int(tile_n), interpret=bool(interpret))

    result = KMeansResult(carry.centroids, assignments, carry.iteration,
                          evals, inertia)
    if return_stats:
        return result, stats
    return result


# --------------------------------------------------------------------------
# streaming / mini-batch single-pass step (driven by repro.streaming)
# --------------------------------------------------------------------------

class StreamStepOut(NamedTuple):
    """Outputs of one mini-batch :func:`stream_update` step. The
    returned ``ub``/``lb`` are already decayed by this step's centroid
    drift, i.e. valid against the RETURNED centroids — exactly what the
    caller's per-shard bound cache wants to store."""
    centroids: jnp.ndarray    # (K, D) after the decayed update
    counts: jnp.ndarray       # (K,) decayed effective counts
    assignments: jnp.ndarray  # (B,)
    ub: jnp.ndarray           # (B,) post-move upper bounds
    lb: jnp.ndarray           # (B, G) post-move lower bounds
    pairs: jnp.ndarray        # f32: point-centroid pairs scored
    gmax: jnp.ndarray         # int32: surviving-group high-water
    drift: jnp.ndarray        # (K,) this step's per-centroid drift
    gdrift: jnp.ndarray       # (G,) this step's per-group max drift
    batch_counts: jnp.ndarray  # (K,) points of THIS batch per centroid
    batch_cost: jnp.ndarray   # f32 sum(ub^2) pre-move: an upper-bound
                              # estimate of the batch's inertia


@jax.jit
def stream_bounds(points, centroids, assignments, ub, lb):
    """Point-level filter over CARRIED (drift-inflated) bounds — the
    first half of ``move_and_bounds`` without the centroid move. ``ub``
    must upper-bound d(x, centroids[assignments]) and ``lb`` must
    lower-bound the per-group min excluding the assignment (the shard
    cache's :func:`repro.streaming.inflate_bounds` contract).

    Returns ``(ub_t, need, n_cand, n_tightened)``: tightened upper
    bounds, the pending candidate mask, its popcount, and how many
    exact own-centroid distances were spent tightening.
    """
    glb = jnp.min(lb, axis=1)
    maybe = ub > glb
    d_own = rowwise_dists(points, centroids[assignments])
    ub_t = jnp.where(maybe, d_own, ub)
    need = ub_t > glb
    return ub_t, need, jnp.sum(need.astype(jnp.int32)), jnp.sum(
        maybe.astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=(
    "k", "n_groups", "cap_n", "cap_g", "chunk"))
def stream_update(points, centroids, counts, decay, groups, members, gsize,
                  assignments, ub_t, lb, need, *, k, n_groups, cap_n,
                  cap_g, chunk=2048):
    """One mini-batch against EXTERNAL carry (centroids + effective
    counts): the engine's two-level compacted candidate pass, then a
    decayed count-weighted centroid update, then post-move bound decay.

    This is the reusable single-pass step behind
    :class:`repro.streaming.StreamingKMeans`. The update is the
    mini-batch EMA ``c <- (decay * n_c * c + sum_batch) / (decay * n_c
    + b_c)``: ``decay=1`` is pure count-weighting (per-centroid 1/n
    learning rate), ``decay<1`` caps the memory at ~1/(1-decay)
    batches. ``cap_n`` MUST be >= the candidate count (the caller syncs
    it via :func:`stream_bounds`); ``cap_g`` is a guess — the pass's
    ``lax.cond`` spills to the dense branch when it is exceeded, and
    the returned ``gmax`` recalibrates the next visit.
    """
    new_as, nub, nlb, pairs, gmax = compact_candidate_pass(
        points, centroids, assignments, ub_t, lb, groups, members, gsize,
        need, cap_n=cap_n, cap_g=cap_g, n_groups=n_groups, chunk=chunk,
        opt_sq=True)
    bsums, bcounts = centroid_sums(points, new_as, k)

    dec = counts * decay
    new_counts = dec + bcounts
    sums = dec[:, None] * centroids + bsums
    # fractional decayed counts: guard with an epsilon, not the batch
    # fit's max(counts, 1) (which assumes integer counts)
    new_c = jnp.where(new_counts[:, None] > 1e-6,
                      sums / jnp.maximum(new_counts, 1e-6)[:, None],
                      centroids)

    drift = jnp.linalg.norm(new_c - centroids, axis=-1)
    # clamp: segment_max of an EMPTY group is -inf, which the batch
    # loop tolerates but would poison the caller's cumulative drift
    # ledger (inf - inf = NaN on the next inflation)
    gdrift = jnp.maximum(
        jax.ops.segment_max(drift, groups, num_segments=n_groups), 0.0)
    out_ub = nub + drift[new_as]
    out_lb = jnp.maximum(nlb - gdrift[None, :], 0.0)
    return StreamStepOut(new_c, new_counts, new_as, out_ub, out_lb,
                         pairs, gmax, drift, gdrift, bcounts,
                         jnp.sum(nub * nub))
