"""Device-resident filtered K-means execution engine.

This is the single executor behind the KPynq filter family — ONE pass
core, three drivers. The layering (see ``docs/architecture.md``):

* :class:`PassCore` — the candidate-pass dispatch (oracle / compact /
  ladder / pallas) plus the :func:`move_and_bounds` epilogue, the only
  copy of the filtered iteration (``_loop_body`` is the only
  candidate-pass loop body in the repo);
* :class:`Reducer` — the collective axis: identity locally,
  psum/pmax over mesh axes inside ``shard_map`` (with optional int8
  compression of the (K, D) partial-sums payload only);
* the centroid-update strategies — :data:`CONVERGENCE_UPDATE` (batch
  mean + tol-on-drift convergence) vs :data:`EMA_UPDATE` (the
  streaming decayed count-weighted EMA);
* ``sample_weight`` threads through :func:`centroid_sums`, the
  inertia, and the EMA's effective counts in this one place, so every
  backend x every driver is weighted by the same implementation
  (weights never touch bounds or filters — work saving is unchanged,
  and ``None``/uniform-1.0 weights are bit-identical).

The three drivers are thin instantiations: :func:`fit` (this module) =
PassCore + local reducer + convergence, host-picked capacity buckets;
``repro.core.distributed.distributed_yinyang`` = the same
:func:`fit_core` inside ``shard_map`` + psum reducer + the in-trace
capacity ladder; ``repro.streaming.StreamingKMeans`` =
:func:`stream_step` = one PassCore pass + (local|psum) reducer + EMA.

The iteration loop realises BOTH filter levels as skipped work:

* the whole fit runs under ``lax.while_loop`` — zero host round-trips
  per iteration. The only host syncs are capacity-bucket transitions
  (O(log N) of them, counted in :class:`EngineStats`), not one per
  iteration like the legacy ``yinyang_compact`` driver;
* **point-level compaction**: surviving points are stream-compacted
  into a padded buffer whose capacity comes from a fixed power-of-two
  lattice, so XLA compiles a small, bounded set of programs;
* **centroid-level compaction**: each candidate's *surviving groups*
  are compacted into a padded per-point group bucket and only those
  groups' centroids are gathered for the distance pass — the
  group-level filter becomes skipped FLOPs, not just bookkeeping;
* **norm caching**: ``||x||^2`` is computed ONCE PER FIT and carried
  through the ``lax.while_loop`` (``EngineCarry.x2``); ``||c||^2`` is
  computed once per iteration by :func:`move_and_bounds` and shared by
  the own-distance refresh and the next candidate pass
  (``EngineCarry.c2``). On the compact backend the own-distance
  refresh itself runs on the COMPACTED survivor buffer instead of all
  N rows (``refresh_ub=True`` in :func:`compact_candidate_pass`);
* the Pallas block-skip kernel (``repro.kernels.grouped_assign``) slots
  in as the TPU backend behind the same interface;
* the bucket machinery also exists fully IN-TRACE for hostless loops
  (:func:`cap_ladders` / :func:`select_bucket` /
  :func:`ladder_candidate_pass`): a static capacity lattice switched
  per iteration with ``lax.switch`` — what ``repro.core.distributed``
  runs inside its ``shard_map`` body, where a host sync is not an
  option.

Backend selection (``backend=`` on :func:`fit`):

``"oracle"``
    Masked-dense pass over all N points every iteration — computes every
    distance and discards the filtered ones. Ground truth / debugging.
``"compact"``
    The two-level compaction path above. Default off-TPU: on CPU/GPU
    this is what turns filter rates into wall-clock speedup.
``"pallas"``
    Group-granular block-skip Pallas kernel (``interpret=True`` runs it
    anywhere). Default on TPU, where per-point gathers are hostile but
    skipping whole (tile_n x group) blocks is free.
``"lloyd"``
    The jit-cached reference Lloyd loop — one dense GEMM per
    iteration, no filter bookkeeping. The right call below the
    work crossover (see ``EngineConfig.lloyd_max_work``) and a
    legitimate autotuner outcome for filter-hostile shapes.
``"auto"``
    Consults the tuned configuration (see below) when one exists;
    otherwise ``"lloyd"`` for tiny problems (``n * k <=
    lloyd_max_work``), ``"pallas"`` on TPU, ``"compact"`` elsewhere.

Autotuning (``tune=`` on :func:`fit`): every fixed knob of this engine
— ``tile_n``, ``min_cap``, ``chunk``, the group-gather crossover, the
downshift hysteresis, the backend itself — is a measured choice, and
the right value depends on (platform, N, K, D). ``tune="auto"``
(default) consults the persistent tuning cache
(:mod:`repro.tune`, ``~/.cache/repro_kmeans_tune.json`` unless
``REPRO_KMEANS_TUNE_CACHE`` overrides) and uses the cached winner for
this problem signature; ``tune="force"`` runs the measured search on a
cache miss and persists the winner; ``tune="off"`` uses the built-in
defaults. Tuned configurations change SHAPES AND DISPATCH ONLY — the
fixed point (assignments, inertia) is bit-identical for every
configuration (``tests/test_tune.py`` asserts this).

Every backend is exact: fixed points are identical to Lloyd's
(``tests/test_engine.py`` checks assignments/inertia parity across the
whole matrix). The split-loop construction (candidate pass for
iteration *i* runs at the top of body *i+1*, with a single epilogue
pass after the loop) is what lets the bucket conditions live in the
``while_loop`` *cond* without ever re-doing or skipping work.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import io_callback

from ..obs import ring as _obs_ring
from ..obs.metrics import normalize_obs
from ..obs.ring import N_COUNTERS, RING_COLUMNS
from .distances import (pairwise_dists, pairwise_sq_dists, row_norms_sq,
                        rowwise_dists)
from .kmeans import (EvalCount, KMeansResult, _init_filter_state,
                     centroid_sums, centroids_from_sums, group_centroids,
                     lloyd)

BACKENDS = ("oracle", "compact", "pallas")

# Default backend="auto" work crossover: problems with n*k at or below
# this route straight to the reference Lloyd loop — BENCH_kmeans.json
# shows the dense (N, K) GEMM beating the filtered engine at uci-small
# scale, where one fused matmul per iteration is cheaper than any bound
# bookkeeping. The fixed point is identical (tests/test_engine.py
# parity matrix), only distance_evals differ. The per-signature tuned
# value lives in EngineConfig.lloyd_max_work.
AUTO_LLOYD_MAX_WORK = 1 << 17

# jit-cached Lloyd for the tiny-problem route: calling the bare
# function would re-trace its while_loop on every fit, costing more
# than the fit itself at these sizes
_lloyd_jit = functools.partial(jax.jit, static_argnames=(
    "max_iters", "tol"))(lambda points, init_c, weights, *, max_iters,
                         tol: lloyd(points, init_c, max_iters, tol,
                                    weights=weights))


# --------------------------------------------------------------------------
# engine configuration (the autotuner's search space)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """One point in the engine's configuration space.

    Every field is a measured choice the autotuner (:mod:`repro.tune`)
    searches per (platform, N, K, D) signature; none of them affects
    the fixed point — only shapes, dispatch, and wall-clock.

    backend : "auto" | "oracle" | "compact" | "pallas" | "lloyd"
        Candidate-pass realisation. "auto" defers to the platform /
        ``lloyd_max_work`` rules in :func:`fit`.
    tile_n : point-tile height of the Pallas block-skip kernels.
    min_cap : floor of the power-of-two point-capacity lattice.
    chunk : largest compacted candidate count for which the per-point
        group-gather path is considered (above it the dense GEMM on
        the survivor buffer wins; XLA gathers scale worse than BLAS).
    group_gather_factor : the group-gather path is taken only when
        ``cap_g * l_max * group_gather_factor <= k`` — i.e. the group
        filter must remove at least this multiple of K before
        per-point gathers beat one dense (cap_n, K) matmul.
    down_n / down_g : downshift hysteresis. A running segment exits to
        a smaller bucket when ``n_cand * down_n <= cap_n`` (resp.
        ``gmax * down_g <= cap_g``); 0 disables that downshift axis.
    refresh_in_pass : where the own-distance refresh of *maybe*
        survivors runs on the compact backend. True = on the compacted
        survivor buffer inside the candidate pass (no full-N rowwise
        work, but capacity buckets are sized by the larger maybe-count);
        False = as a full-N masked rowwise pass in
        :func:`move_and_bounds` (costs one gather+dot over N per
        iteration, but the refresh prunes the candidate set BEFORE
        compaction, so buckets track the smaller need-count). Which
        side wins is a measured shape property — gather-hostile wide-D
        problems favour True, GEMM-strong small-D CPU shapes False.
    lloyd_max_work : backend="auto" routes ``n * k <= lloyd_max_work``
        straight to the dense Lloyd loop.
    """
    backend: str = "auto"
    tile_n: int = 256
    min_cap: int = 256
    chunk: int = 2048
    group_gather_factor: int = 4
    down_n: int = 2
    down_g: int = 4
    refresh_in_pass: bool = False
    lloyd_max_work: int = AUTO_LLOYD_MAX_WORK

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "EngineConfig":
        """Tolerant inverse of :meth:`to_dict` (unknown keys from a
        newer/older cache version are dropped, missing keys default)."""
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in names})

    def replace(self, **kw) -> "EngineConfig":
        return dataclasses.replace(self, **kw)


DEFAULT_CONFIG = EngineConfig()


def use_groups_decision(*, cap_n: int, cap_g: int, l_max: int, k: int,
                        chunk: int, group_gather_factor: int) -> bool:
    """The compact pass's group-gather vs dense-GEMM crossover — THE
    single copy of the rule, shared by the pass (trace-time), the
    driver (per-segment stats), and the tuner (search space)."""
    return (cap_g * l_max * group_gather_factor <= k) and cap_n <= chunk


# --------------------------------------------------------------------------
# the pass core's two strategy axes: Reducer (which collective) and
# the centroid-update rule (which epilogue)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Reducer:
    """Collective parameterisation of the pass core.

    The ONLY thing that differs between the single-device fit and the
    ``shard_map`` fit is which reduction joins the per-shard centroid
    partial sums (and the scalar telemetry): identity locally,
    ``lax.psum``/``pmax`` over the mesh axes in the distributed
    drivers. Frozen + hashable so a Reducer can ride in a jit-static
    :class:`PassCore`.

    ``compress=True`` int8-compresses the (K, D) partial-sums payload
    ONLY (:meth:`sums`); counts, weights and scalars always reduce
    exactly (:meth:`add` / :meth:`max`).
    """
    axes: tuple = ()               # () = local (identity reductions)
    compress: bool = False

    @property
    def is_local(self) -> bool:
        return not self.axes

    def sums(self, x):
        """Reduce the (K, D) centroid partial sums — the one payload
        eligible for int8 compression (error-feedback-free single-shot
        absmax scaling; relative error ~1/127, self-correcting across
        iterations)."""
        if not self.axes:
            return x
        if not self.compress:
            return jax.lax.psum(x, self.axes)
        scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        return jax.lax.psum(q.astype(jnp.float32) * scale, self.axes)

    def add(self, x):
        """Exact sum reduction (counts, eval counters, inertia)."""
        return x if not self.axes else jax.lax.psum(x, self.axes)

    def max(self, x):
        """Max reduction (candidate counts, group high-waters)."""
        return x if not self.axes else jax.lax.pmax(x, self.axes)


LOCAL_REDUCER = Reducer()


@dataclasses.dataclass(frozen=True)
class ConvergenceUpdate:
    """Batch-fit centroid rule: mean of the reduced weighted sums,
    empty clusters keep their previous centroid. Paired with the
    tol-on-drift convergence test of the fit loops. ``clamp_gdrift``
    stays False: an empty Yinyang group's ``segment_max`` drift is
    ``-inf``, which the batch bound decay deliberately turns into a
    vacuous (+inf) lower bound."""
    clamp_gdrift: bool = False

    def apply(self, sums, counts, centroids, carry_counts, decay):
        return centroids_from_sums(sums, counts, centroids), counts


@dataclasses.dataclass(frozen=True)
class EMAUpdate:
    """Streaming centroid rule: the decayed count-weighted EMA
    ``c <- (decay * n_c * c + sum_batch) / (decay * n_c + b_c)`` —
    ``decay=1`` is pure count-weighting (per-centroid 1/n learning
    rate), ``decay<1`` caps the memory at ~1/(1-decay) batches. THE
    single copy of the update rule, shared by the local and sharded
    streaming steps. ``clamp_gdrift=True``: an empty group's -inf
    drift would otherwise poison the caller's cumulative drift ledger
    (inf - inf = NaN on the next inflation)."""
    clamp_gdrift: bool = True

    def apply(self, sums, counts, centroids, carry_counts, decay):
        dec = carry_counts * decay
        new_counts = dec + counts
        tot = dec[:, None] * centroids + sums
        # fractional decayed counts: guard with an epsilon, not the
        # batch fit's max(counts, 1) (which assumes integer counts)
        new_c = jnp.where(new_counts[:, None] > 1e-6,
                          tot / jnp.maximum(new_counts, 1e-6)[:, None],
                          centroids)
        return new_c, new_counts


CONVERGENCE_UPDATE = ConvergenceUpdate()
EMA_UPDATE = EMAUpdate()


class MoveOut(NamedTuple):
    """Everything :func:`move_and_bounds` produces. Batch drivers read
    ``centroids``/``c2``/``ub``/``lb``/``need``/``shift``/``tightened``;
    the streaming step additionally reads ``counts`` (the carried
    effective counts after the EMA), ``drift``/``gdrift`` (fed to the
    host drift ledger) and ``batch_counts`` (this batch's per-centroid
    weighted mass, pre-EMA)."""
    centroids: jnp.ndarray     # (K, D) after the update rule
    c2: jnp.ndarray            # (K,) ||centroids||^2, once per iteration
    counts: jnp.ndarray        # (K,) rule-dependent carried counts
    ub: jnp.ndarray            # (N,) drift-inflated (maybe refreshed)
    lb: jnp.ndarray            # (N, G) drift-decayed
    need: jnp.ndarray          # (N,) pending candidate mask
    shift: jnp.ndarray         # f32 max centroid drift
    tightened: jnp.ndarray     # f32 own-distance refreshes implied
    drift: jnp.ndarray         # (K,) per-centroid drift this move
    gdrift: jnp.ndarray        # (G,) per-group max drift this move
    batch_counts: jnp.ndarray  # (K,) this pass's weighted mass


# --------------------------------------------------------------------------
# shared per-iteration pieces (also consumed by compact.py / distributed.py)
# --------------------------------------------------------------------------

def move_and_bounds(points, centroids, assignments, ub, lb, groups,
                    *, k: int, n_groups: int,
                    reducer: Reducer = LOCAL_REDUCER,
                    update=CONVERGENCE_UPDATE, counts=None, decay=None,
                    weights=None, x2=None, refresh: bool = True):
    """Centroid move + triangle-inequality bound maintenance + the
    point-level filter — the pass core's move half, shared VERBATIM by
    every driver (batch, sharded, streaming).

    ``reducer``: which collective joins the per-shard centroid partial
    sums (identity locally, psum over the mesh axes in the distributed
    drivers — int8 compression applies to the (K, D) sums only).

    ``update``: the centroid rule — :data:`CONVERGENCE_UPDATE` (batch
    mean, tol-convergence drivers) or :data:`EMA_UPDATE` (decayed
    count-weighted streaming EMA; needs ``counts``/``decay``).

    ``weights``: optional (N,) per-point sample weights. They enter the
    partial sums and counts ONLY — bounds and filter decisions are
    weight-independent, and ``weights=None`` compiles the exact
    pre-weight program (uniform weights of 1.0 are bit-identical to
    it, since multiplying by 1.0f is exact).

    ``x2``: cached ``||x||^2`` row norms (computed once per fit by the
    callers); ``None`` falls back to the diff-form rowwise distance.
    The new centroids' ``||c||^2`` is computed here ONCE and returned
    (``MoveOut.c2``) so the caller can share it with the following
    candidate pass instead of recomputing it.

    ``refresh=False`` (the compact backend's in-pass placement, and the
    streaming step where the refresh belongs to the NEXT batch's
    ``stream_bounds``) skips the own-distance refresh entirely — the
    returned ``need`` is then the *maybe* mask (``ub > glb`` on
    drift-inflated bounds) and the refresh happens on the compacted
    survivor buffer inside :func:`compact_candidate_pass`
    (``refresh_ub=True``), so the full-N gather + rowwise pass
    disappears from the hot loop.

    Returns a :class:`MoveOut`.
    """
    sums, bcounts = centroid_sums(points, assignments, k, weights=weights)
    with jax.named_scope("kpynq/reduce"):
        sums = reducer.sums(sums)
        bcounts = reducer.add(bcounts)
    new_c, new_counts = update.apply(sums, bcounts, centroids, counts,
                                     decay)
    new_c2 = row_norms_sq(new_c)                       # once per iteration

    drift = jnp.linalg.norm(new_c - centroids, axis=-1)
    group_drift = jax.ops.segment_max(drift, groups, num_segments=n_groups)
    if update.clamp_gdrift:
        group_drift = jnp.maximum(group_drift, 0.0)
    shift = jnp.max(drift)
    ub = ub + drift[assignments]
    lb_dec = jnp.maximum(lb - group_drift[None, :], 0.0)
    glb = jnp.min(lb_dec, axis=1)
    maybe = ub > glb
    if refresh:
        with jax.named_scope("kpynq/refresh"):
            if x2 is None:
                d_own = rowwise_dists(points, new_c[assignments])
            else:
                own = new_c[assignments]
                d_own = jnp.sqrt(jnp.maximum(
                    x2 - 2.0 * jnp.sum(points.astype(jnp.float32) * own,
                                       axis=-1) + new_c2[assignments], 0.0))
            ub_t = jnp.where(maybe, d_own, ub)
            need = ub_t > glb
    else:
        ub_t = ub
        need = maybe
    return MoveOut(new_c, new_c2, new_counts, ub_t, lb_dec, need, shift,
                   jnp.sum(maybe.astype(jnp.float32)), drift, group_drift,
                   bcounts)


def dense_candidate_pass(points, new_c, assignments, ub_t, lb, groups, need,
                         *, n_groups: int, opt_sq: bool = True,
                         x2=None, c2=None):
    """Masked-dense candidate pass over all N points (oracle backend and
    the per-shard distributed step). Group filter applied as a mask —
    exact semantics, no skipped FLOPs.

    ``opt_sq=True`` (default) runs min/argmin on SQUARED distances and
    sqrts only the reduced outputs (monotone => bit-identical results,
    one fewer (N, K) sqrt pass + HBM round-trip). ``x2``/``c2``:
    cached squared norms (see :mod:`repro.core.distances`).

    Returns ``(new_assign, new_ub, new_lb, n_pairs)``.
    """
    n = points.shape[0]
    rows = jnp.arange(n)
    group_need = need[:, None] & (lb < ub_t[:, None])              # (N, G)
    cand = group_need[:, groups]                                    # (N, K)
    pairs = jnp.sum(cand.astype(jnp.float32))

    if opt_sq:
        d_cand = jnp.where(cand, pairwise_sq_dists(points, new_c, x2, c2),
                           jnp.inf)
        best = jnp.argmin(d_cand, axis=1).astype(jnp.int32)
        best_d = jnp.sqrt(jnp.min(d_cand, axis=1))
    else:
        d_cand = jnp.where(cand, pairwise_dists(points, new_c, x2, c2),
                           jnp.inf)
        best = jnp.argmin(d_cand, axis=1).astype(jnp.int32)
        best_d = jnp.min(d_cand, axis=1)
    changed = best_d < ub_t
    new_assign = jnp.where(changed, best, assignments)
    new_ub = jnp.minimum(ub_t, best_d)

    d_excl = d_cand.at[rows, new_assign].set(jnp.inf)
    lb_comp = jax.ops.segment_min(d_excl.T, groups,
                                  num_segments=n_groups).T          # (N, G)
    if opt_sq:
        lb_comp = jnp.sqrt(lb_comp)
    new_lb = jnp.where(group_need, lb_comp, lb)
    old_group = groups[assignments]
    new_lb = new_lb.at[rows, old_group].min(
        jnp.where(changed, ub_t, jnp.inf))
    return new_assign, new_ub, new_lb, pairs


def compact_candidate_pass(points, new_c, assignments, ub_t, lb, groups,
                           members, gsize, need, *, cap_n: int, cap_g: int,
                           n_groups: int, chunk: int = 2048,
                           use_groups: bool | None = None,
                           opt_sq: bool = True, x2=None, c2=None,
                           refresh_ub: bool = False,
                           group_gather_factor: int = 4):
    """Two-level compacted candidate pass.

    Point level: the ``need`` survivors are stream-compacted into a
    ``cap_n`` buffer (``cap_n`` must be >= the survivor count — the
    engine's while-loop cond guarantees it).

    ``refresh_ub=True`` (the engine's compact backend): ``need`` is the
    *maybe* mask from :func:`move_and_bounds` ``refresh=False`` and the
    exact own-centroid distance is computed HERE, on the compacted
    buffer only — points whose refreshed bound re-filters them simply
    flow through with a tightened ``ub`` and an empty group set (their
    distance rows are masked out), so the full-N rowwise refresh is
    gone while the semantics stay bit-identical.

    Centroid level: each candidate's surviving groups are compacted
    into a ``cap_g``-slot bucket; only those groups' member centroids
    (``members``: (G, Lmax) int32, -1-padded) are gathered and scored.
    The gather-vs-GEMM crossover is :func:`use_groups_decision` (tuned
    via ``group_gather_factor`` / ``chunk`` — see
    :class:`EngineConfig`); ``use_groups=None`` applies it at trace
    time. When the bucket IS compiled in, a runtime ``lax.cond``
    spills to the dense branch whenever some candidate's
    surviving-group count exceeds ``cap_g`` — exactness never depends
    on the bucket guess; the engine reads the returned ``gmax`` to
    upshift the next segment.

    ``x2``/``c2``: cached squared norms (full-size ``x2`` is gathered
    per survivor; ``c2`` is this iteration's centroid norms from
    :func:`move_and_bounds`).

    Returns updated full-size ``(assignments, ub, lb, n_pairs, gmax)``.
    """
    n = points.shape[0]
    k = new_c.shape[0]
    l_max = members.shape[1]
    rows = jnp.arange(cap_n)

    # --- point-level compaction -------------------------------------
    pos = jnp.cumsum(need.astype(jnp.int32)) - 1
    slot = jnp.where(need, pos, cap_n)
    idx = jnp.zeros((cap_n,), jnp.int32).at[slot].set(
        jnp.arange(n, dtype=jnp.int32), mode="drop")
    count = jnp.sum(need.astype(jnp.int32))
    valid = jnp.arange(cap_n) < count

    cpts = points[idx]                                        # (cap, D)
    c_ub = ub_t[idx]
    c_lb = lb[idx]                                            # (cap, G)
    c_as = assignments[idx]
    if c2 is None:
        c2 = row_norms_sq(new_c)
    c_x2 = x2[idx] if x2 is not None else row_norms_sq(cpts)  # (cap,)
    if refresh_ub:
        # own-distance refresh on the compacted buffer (cap_n rows, not
        # N): d(x, c_a) via the cached norms; invalid slots compute
        # garbage that the scatter drops
        own = new_c[c_as]
        c_ub = jnp.sqrt(jnp.maximum(
            c_x2 - 2.0 * jnp.sum(cpts.astype(jnp.float32) * own, axis=-1)
            + c2[c_as], 0.0))
    gneed = (c_lb < c_ub[:, None]) & valid[:, None]           # (cap, G)
    gmax = jnp.max(jnp.sum(gneed.astype(jnp.int32), axis=1))
    # rows that still need any distance work after the (possibly
    # in-pass) refresh — the dense branch's honest eval count
    n_rows = jnp.sum(jnp.any(gneed, axis=1).astype(jnp.float32))

    if use_groups is None:
        use_groups = use_groups_decision(
            cap_n=cap_n, cap_g=cap_g, l_max=l_max, k=k, chunk=chunk,
            group_gather_factor=group_gather_factor)

    def dense_branch(_):
        # one (cap_n, K) GEMM on the survivors
        gmask = gneed[:, groups]                              # (cap, K)
        if opt_sq:
            # min/argmin on squared distances (monotone => identical),
            # sqrt only the (cap,)/(cap, G) reductions: one fewer
            # (cap, K) sqrt pass per iteration.
            d_cand = jnp.where(gmask,
                               pairwise_sq_dists(cpts, new_c, c_x2, c2),
                               jnp.inf)
            bid = jnp.argmin(d_cand, axis=1).astype(jnp.int32)
            bd = jnp.sqrt(jnp.min(d_cand, axis=1))
        else:
            d_cand = jnp.where(gmask,
                               pairwise_dists(cpts, new_c, c_x2, c2),
                               jnp.inf)
            bid = jnp.argmin(d_cand, axis=1).astype(jnp.int32)
            bd = jnp.min(d_cand, axis=1)
        chg = bd < c_ub
        nas = jnp.where(chg, bid, c_as)
        nub = jnp.minimum(c_ub, bd)
        d_excl = d_cand.at[rows, nas].set(jnp.inf)
        lb_comp = jax.ops.segment_min(d_excl.T, groups,
                                      num_segments=n_groups).T
        if opt_sq:
            lb_comp = jnp.sqrt(lb_comp)
        new_clb = jnp.where(gneed, lb_comp, c_lb)
        pairs = n_rows * k
        return nas, nub, new_clb, pairs, chg

    def group_branch(_):
        # centroid-level compaction: padded per-point group bucket
        gpos = jnp.cumsum(gneed.astype(jnp.int32), axis=1) - 1
        gslot = jnp.where(gneed, gpos, cap_g)
        gsel = jnp.full((cap_n, cap_g), n_groups, jnp.int32).at[
            rows[:, None], gslot].set(
            jnp.broadcast_to(jnp.arange(n_groups, dtype=jnp.int32),
                             (cap_n, n_groups)), mode="drop")

        def bucket_pass(x, x2v, gs, cub, cas):
            mem = jnp.take(members, gs, axis=0, mode="fill",
                           fill_value=-1)                # (ch, cap_g, L)
            mem_s = jnp.maximum(mem, 0)
            csel = new_c[mem_s]                          # (ch, cap_g, L, D)
            xf = x.astype(jnp.float32)
            cross = jnp.einsum("nd,ngld->ngl", xf,
                               csel.astype(jnp.float32))
            d2 = jnp.maximum(x2v[:, None, None] - 2.0 * cross + c2[mem_s],
                             0.0)
            ch = x.shape[0]
            # squared-distance reductions, sqrt only the outputs
            dm = jnp.where(mem >= 0, d2, jnp.inf).reshape(ch, -1)
            memf = mem.reshape(ch, -1)
            bcol = jnp.argmin(dm, axis=1)
            bd = jnp.sqrt(jnp.min(dm, axis=1))
            bid = jnp.take_along_axis(memf, bcol[:, None], 1)[:, 0]
            chg = bd < cub
            nas = jnp.where(chg, bid, cas).astype(jnp.int32)
            nub = jnp.minimum(cub, bd)
            d_ex = jnp.where(memf == nas[:, None], jnp.inf, dm)
            smin = jnp.sqrt(jnp.min(d_ex.reshape(ch, cap_g, l_max),
                                    axis=2))
            return nas, nub, smin, chg

        nas, nub, smin, chg = bucket_pass(cpts, c_x2, gsel, c_ub, c_as)
        new_clb = c_lb.at[rows[:, None], gsel].set(smin, mode="drop")
        pairs = jnp.sum(gneed.astype(jnp.float32) * gsize[None, :])
        return nas, nub, new_clb, pairs, chg

    if use_groups:
        nas, nub, new_clb, pairs, chg = jax.lax.cond(
            gmax <= cap_g, group_branch, dense_branch, operand=None)
    else:
        nas, nub, new_clb, pairs, chg = dense_branch(None)

    old_group = jnp.take(groups, c_as)                        # (cap,)
    new_clb = new_clb.at[rows, old_group].min(
        jnp.where(chg, c_ub, jnp.inf))

    # --- scatter survivors back (invalid slots dropped) --------------
    sidx = jnp.where(valid, idx, n)
    assignments = assignments.at[sidx].set(nas, mode="drop")
    ub_out = ub_t.at[sidx].set(nub, mode="drop")
    lb_out = lb.at[sidx].set(new_clb, mode="drop")
    return assignments, ub_out, lb_out, pairs, gmax


def cap_ladders(n: int, n_groups: int, *, min_cap: int = 256,
                max_branches: int = 12):
    """Static (cap_n, cap_g) lattices for the IN-TRACE bucketed pass.

    The batch driver picks capacities on the host between ``_run_loop``
    segments; inside a ``shard_map`` body there is no host to ask, so
    the whole lattice must be fixed at trace time and the shard switches
    between levels with ``lax.switch`` (:func:`ladder_candidate_pass`).
    Levels are the engine's usual power-of-two lattice from ``min_cap``
    up to the shard size (resp. 1 up to ``n_groups``), coarsened until
    the branch product fits ``max_branches`` compiled pass instances:
    interior levels go first, then (only under a budget too small for
    2x2 ladders) the LOW endpoints. The top levels are never dropped —
    ``cap_ns[-1] == n`` is what makes the mandatory upshift in
    :func:`select_bucket` always able to satisfy the pass's
    ``cap_n >= count`` precondition.
    """
    n = max(int(n), 1)
    n_groups = max(int(n_groups), 1)
    cap_ns, c = [], min(_bucket_cap(min_cap, 1, n), n)
    while c < n:
        cap_ns.append(c)
        c *= 2
    cap_ns.append(n)
    cap_gs, g = [], 1
    while g < n_groups:
        cap_gs.append(g)
        g *= 2
    cap_gs.append(n_groups)
    while len(cap_ns) * len(cap_gs) > max(int(max_branches), 1):
        if len(cap_gs) > 2 and len(cap_gs) >= len(cap_ns):
            del cap_gs[len(cap_gs) // 2]
        elif len(cap_ns) > 2:
            del cap_ns[len(cap_ns) // 2]
        elif len(cap_gs) > 1:
            del cap_gs[0]
        elif len(cap_ns) > 1:
            del cap_ns[0]
        else:
            break
    return tuple(cap_ns), tuple(cap_gs)


def select_bucket(n_cand, gmax, level_n, level_g, *, cap_ns, cap_gs,
                  down_n: int = 2, down_g: int = 4):
    """Shard-local bucket transition — the traced analogue of the host
    bucket picker in :func:`fit`.

    Upshifts are mandatory the moment the pending candidate count (or
    the observed surviving-group high-water) leaves its level;
    downshifts only fire past the tuned hysteresis factors
    (``EngineConfig.down_n`` / ``down_g``; 0 disables that axis), and
    never on ``gmax == 0`` (no candidates seen — not evidence that one
    group slot suffices). Returns the next ``(level_n, level_g)``.
    """
    cn = jnp.asarray(cap_ns, jnp.int32)
    cg = jnp.asarray(cap_gs, jnp.int32)
    req_n = jnp.minimum(jnp.searchsorted(cn, n_cand),
                        len(cap_ns) - 1).astype(jnp.int32)
    move = req_n > level_n
    if down_n:
        move = jnp.logical_or(move, jnp.logical_and(
            req_n < level_n, n_cand * down_n <= cn[level_n]))
    new_n = jnp.where(move, req_n, level_n)

    req_g = jnp.minimum(jnp.searchsorted(cg, jnp.maximum(gmax, 1)),
                        len(cap_gs) - 1).astype(jnp.int32)
    move_g = req_g > level_g
    if down_g:
        move_g = jnp.logical_or(move_g, jnp.logical_and(
            jnp.logical_and(gmax > 0, req_g < level_g),
            gmax * down_g <= cg[level_g]))
    new_g = jnp.where(move_g, req_g, level_g)
    return new_n, new_g


def ladder_candidate_pass(points, new_c, assignments, ub_t, lb, groups,
                          members, gsize, need, level_n, level_g, *,
                          cap_ns, cap_gs, n_groups: int, chunk: int = 2048,
                          group_gather_factor: int = 4, opt_sq: bool = True,
                          x2=None, c2=None, refresh_ub: bool = False):
    """:func:`compact_candidate_pass` at a TRACED capacity level.

    One ``lax.switch`` over the static ``cap_ns`` x ``cap_gs`` lattice
    (:func:`cap_ladders`); each branch is the compact pass compiled at
    one (cap_n, cap_g) pair, with the gather-vs-GEMM crossover
    (:func:`use_groups_decision`) resolved per branch at trace time.
    This is what lets a ``shard_map`` body run the two-level compaction
    with SHARD-LOCAL bucket choices and zero host syncs: every shard
    executes only its selected branch, and no collectives live inside
    the branches so shards in different buckets cannot desynchronise.
    Correctness needs ``cap_ns[level_n] >= sum(need)`` — the mandatory
    upshift in :func:`select_bucket` maintains it; ``cap_g`` stays a
    guess (the pass's ``lax.cond`` spills to its dense branch).
    """
    branches = []
    for cn in cap_ns:
        for cg in cap_gs:
            def branch(_, cn=cn, cg=cg):
                return compact_candidate_pass(
                    points, new_c, assignments, ub_t, lb, groups, members,
                    gsize, need, cap_n=cn, cap_g=cg, n_groups=n_groups,
                    chunk=chunk, use_groups=None, opt_sq=opt_sq, x2=x2,
                    c2=c2, refresh_ub=refresh_ub,
                    group_gather_factor=group_gather_factor)
            branches.append(branch)
    if len(branches) == 1:
        return branches[0](None)
    index = level_n * len(cap_gs) + level_g
    return jax.lax.switch(index, branches, None)


def pallas_candidate_pass(points, new_c, assignments, ub_t, lb, groups,
                          members, gsize, need, *, n_groups: int,
                          tile_n: int = 256, interpret: bool = False,
                          x2=None, c2=None):
    """Candidate pass through the grouped block-skip Pallas kernel.

    The (point, group) filter decisions become a (N/tile_n, G) block
    mask; the kernel runs the distance matmul only for live blocks and
    returns the global (min, argmin) plus per-group (min, argmin,
    second-min) — exactly what the Yinyang lower-bound refresh needs,
    with no (N, K) distance matrix ever materialised. Cached squared
    norms (``x2`` per point, ``c2`` per centroid) are threaded into
    the kernel so it never recomputes them.
    """
    from ..kernels import build_group_block_mask, grouped_assign

    n = points.shape[0]
    rows = jnp.arange(n)
    group_need = need[:, None] & (lb < ub_t[:, None])              # (N, G)
    mask = build_group_block_mask(group_need, tile_n=tile_n)       # (gn, G)
    mem_s = jnp.maximum(members, 0)
    c_grouped = new_c[mem_s]                                # (G, Lmax, D)
    c2g = None if c2 is None else c2[mem_s]                 # (G, Lmax)
    best2, idx, gmin, garg, gmin2 = grouped_assign(
        points, c_grouped, members, mask, tile_n=tile_n,
        interpret=interpret, x2=x2, c2g=c2g)

    best_d = jnp.sqrt(best2)
    changed = best_d < ub_t
    new_assign = jnp.where(changed, idx, assignments)
    new_ub = jnp.minimum(ub_t, best_d)

    # per-group min excluding the (new) assigned centroid: the group
    # argmin collides with the assignment iff the assignment came from
    # that group, in which case the second-min is the excluded min.
    lb_comp = jnp.sqrt(jnp.where(garg == new_assign[:, None], gmin2, gmin))
    new_lb = jnp.where(group_need, lb_comp, lb)
    old_group = groups[assignments]
    new_lb = new_lb.at[rows, old_group].min(
        jnp.where(changed, ub_t, jnp.inf))
    pairs = jnp.float32(tile_n) * jnp.sum(
        mask.astype(jnp.float32) * gsize[None, :])
    return new_assign, new_ub, new_lb, pairs


# --------------------------------------------------------------------------
# the device-resident loop
# --------------------------------------------------------------------------

class EngineCarry(NamedTuple):
    """while_loop carry. ``ub``/``lb``/``need`` describe the PENDING
    candidate pass (iteration ``iteration``'s second half), which the
    next loop body — or the epilogue — executes. ``x2`` is the
    fit-constant point norms; ``c2`` is the CURRENT centroids' norms
    (refreshed once per iteration by :func:`move_and_bounds`)."""
    iteration: jnp.ndarray    # int32: completed move+bounds iterations
    centroids: jnp.ndarray    # (K, D)
    c2: jnp.ndarray           # (K,) ||centroids||^2, once per iteration
    assignments: jnp.ndarray  # (N,)
    ub: jnp.ndarray           # (N,) tightened upper bounds
    lb: jnp.ndarray           # (N, G) decayed lower bounds
    x2: jnp.ndarray           # (N,) ||x||^2, computed ONCE per fit
    need: jnp.ndarray         # (N,) pending candidate mask
    n_cand: jnp.ndarray       # int32 = sum(need)
    gmax: jnp.ndarray         # int32 max surviving groups per candidate,
                              # as observed by the LAST executed pass
    shift: jnp.ndarray        # f32 max centroid drift
    evals: EvalCount
    ring: jnp.ndarray         # (ring_iters, N_COUNTERS) telemetry ring
                              # (see repro.obs.ring); (0, C) when off


@dataclasses.dataclass
class EngineStats:
    """Execution telemetry: the 'no per-iteration host sync' claim is
    checkable as ``host_syncs << n_iters``; ``use_groups`` records the
    gather-vs-GEMM decision per compact segment (parallel to
    ``caps_history``); ``x2_evals`` states the norm-carry contract of
    the constructed trace — ``||x||^2`` enters via ``EngineCarry.x2``
    so exactly one full-N norm computation exists per fit by
    construction (it is structural, not a runtime counter;
    ``tests/test_tune.py`` verifies it by counting real
    ``row_norms_sq`` calls); ``config`` is the resolved
    :class:`EngineConfig` actually used.

    With observability enabled (``fit(obs=...)``) the stats carry the
    drained telemetry ring: ``ring`` is the trimmed
    ``(n_iters + 1, C)`` numpy buffer (column layout ``ring_columns``
    = :data:`repro.obs.ring.RING_COLUMNS`; final row = epilogue),
    ``init_evals`` the distance evals charged at filter-state init so
    ``init_evals + ring[:, evals].sum() == result.distance_evals``
    exactly. The distributed driver additionally fills
    ``shard_rings`` (S, n_iters + 1, C) — per-shard, pre-reduction —
    and ``shard_skew`` (per-iteration max/mean work imbalance)."""
    backend: str = ""
    n_iters: int = 0
    host_syncs: int = 0
    bucket_switches: int = 0
    caps_history: list = dataclasses.field(default_factory=list)
    use_groups: list = dataclasses.field(default_factory=list)
    x2_evals: int = 0
    config: dict = dataclasses.field(default_factory=dict)
    n_points: int = 0
    ring: np.ndarray | None = None
    ring_columns: tuple = RING_COLUMNS
    init_evals: float = 0.0
    shard_rings: np.ndarray | None = None
    shard_skew: np.ndarray | None = None

    def telemetry(self) -> dict | None:
        """Headline ring summary (iters, mean candidate fraction, total
        evals, ...) — what the benchmark records per dataset. ``None``
        when the fit ran without the ring."""
        if self.ring is None:
            return None
        out = _obs_ring.summarize_ring(self.ring, self.n_points,
                                       init_evals=self.init_evals)
        if self.shard_skew is not None and len(self.shard_skew):
            out["mean_shard_skew"] = float(np.mean(self.shard_skew))
            out["max_shard_skew"] = float(np.max(self.shard_skew))
        return out

    def to_dict(self) -> dict:
        """JSON-serializable view (numpy rings -> nested lists), for
        event logs / benchmark payloads."""
        out = {
            "backend": self.backend,
            "n_iters": int(self.n_iters),
            "host_syncs": int(self.host_syncs),
            "bucket_switches": int(self.bucket_switches),
            "caps_history": [list(c) for c in self.caps_history],
            "use_groups": [bool(u) for u in self.use_groups],
            "x2_evals": int(self.x2_evals),
            "config": dict(self.config),
            "n_points": int(self.n_points),
        }
        if self.ring is not None:
            out["ring_columns"] = list(self.ring_columns)
            out["ring"] = np.asarray(self.ring, np.float64).tolist()
            out["init_evals"] = float(self.init_evals)
            out["telemetry"] = self.telemetry()
        if self.shard_skew is not None:
            out["shard_skew"] = np.asarray(
                self.shard_skew, np.float64).tolist()
        return out


@dataclasses.dataclass(frozen=True)
class PassCore:
    """THE filtered-iteration core: one candidate-pass dispatch + one
    move/bounds epilogue, parameterised by a :class:`Reducer` — the
    single implementation behind ``engine.fit`` (local reducer,
    host-picked buckets), ``repro.core.distributed`` (psum reducer,
    in-trace capacity ladder) and ``repro.streaming`` (single step +
    EMA epilogue).

    ``backend``: the candidate-pass realisation — ``"oracle"``
    (masked dense), ``"compact"`` (two-level compaction at the static
    ``cap_n``/``cap_g``), ``"ladder"`` (compaction switched over the
    static ``cap_ns`` x ``cap_gs`` lattice with ``lax.switch`` —
    what a ``shard_map`` body runs, where a host bucket pick is not an
    option) or ``"pallas"`` (group-granular block-skip kernel).

    Frozen/hashable so a core is a jit-static argument: every field is
    a shape/dispatch choice, none affects the fixed point.
    """
    backend: str
    k: int
    n_groups: int
    reducer: Reducer = LOCAL_REDUCER
    cap_n: int = 0                 # static caps (compact backend)
    cap_g: int = 0
    cap_ns: tuple = ()             # capacity lattice (ladder backend)
    cap_gs: tuple = ()
    chunk: int = 2048
    tile_n: int = 256
    group_gather_factor: int = 4
    down_n: int = 2
    down_g: int = 4
    refresh_in_pass: bool = False
    use_groups: bool | None = None
    interpret: bool = False
    # opt_sq=False exists for analysis artifacts only (the dry-run's
    # A/B of the squared-distance reductions); every driver runs True
    opt_sq: bool = True
    # telemetry-ring rows carried through the loop (0 = ring disabled;
    # the drivers set max_iters + 1 so the epilogue gets the last row).
    # Shape/dispatch only — the ring never feeds back into the fit.
    ring_iters: int = 0
    # emit each ring row as it is written via io_callback (see
    # repro.obs.ring.add_ring_listener); requires ring_iters > 0
    live_drain: bool = False

    @classmethod
    def from_config(cls, cfg: EngineConfig, *, backend: str, k: int,
                    n_groups: int, **kw) -> "PassCore":
        """Lift the tuned knobs of an :class:`EngineConfig` into a
        core; ``kw`` pins the per-driver fields (caps/ladder/reducer)."""
        return cls(backend=backend, k=k, n_groups=n_groups,
                   chunk=cfg.chunk, tile_n=cfg.tile_n,
                   group_gather_factor=cfg.group_gather_factor,
                   down_n=cfg.down_n, down_g=cfg.down_g,
                   refresh_in_pass=cfg.refresh_in_pass, **kw)

    @property
    def refresh_in_move(self) -> bool:
        """Where the own-distance refresh runs: in
        :func:`move_and_bounds` (full-N rowwise) unless the compacting
        backends place it on the survivor buffer."""
        return not (self.backend in ("compact", "ladder")
                    and self.refresh_in_pass)

    def candidate_pass(self, points, centroids, assignments, ub, lb, need,
                       groups, members, gsize, *, x2, c2,
                       level_n=None, level_g=None):
        """Backend dispatch, normalised to
        ``(assign, ub, lb, pairs, gmax)``."""
        if self.backend == "oracle":
            out = dense_candidate_pass(
                points, centroids, assignments, ub, lb, groups, need,
                n_groups=self.n_groups, opt_sq=self.opt_sq, x2=x2, c2=c2)
            return out + (jnp.int32(0),)
        if self.backend == "pallas":
            out = pallas_candidate_pass(
                points, centroids, assignments, ub, lb, groups, members,
                gsize, need, n_groups=self.n_groups, tile_n=self.tile_n,
                interpret=self.interpret, x2=x2, c2=c2)
            return out + (jnp.int32(0),)
        if self.backend == "ladder":
            return ladder_candidate_pass(
                points, centroids, assignments, ub, lb, groups, members,
                gsize, need, level_n, level_g, cap_ns=self.cap_ns,
                cap_gs=self.cap_gs, n_groups=self.n_groups,
                chunk=self.chunk,
                group_gather_factor=self.group_gather_factor, x2=x2,
                c2=c2, refresh_ub=self.refresh_in_pass)
        return compact_candidate_pass(
            points, centroids, assignments, ub, lb, groups, members,
            gsize, need, cap_n=self.cap_n, cap_g=self.cap_g,
            n_groups=self.n_groups, chunk=self.chunk,
            opt_sq=self.opt_sq, x2=x2, c2=c2,
            refresh_ub=self.refresh_in_pass, use_groups=self.use_groups,
            group_gather_factor=self.group_gather_factor)


def _ring_caps(core: PassCore, level_n, level_g, n: int):
    """The (cap_n, cap_g) the candidate pass actually ran at, as fp32
    ring values: the static caps on the compact backend, the traced
    lattice level on the ladder, N/G for the non-compacting passes."""
    if core.backend == "compact":
        return jnp.float32(core.cap_n), jnp.float32(core.cap_g)
    if core.backend == "ladder":
        return (jnp.take(jnp.asarray(core.cap_ns, jnp.float32), level_n),
                jnp.take(jnp.asarray(core.cap_gs, jnp.float32), level_g))
    return jnp.float32(n), jnp.float32(core.n_groups)


def _loop_body(core: PassCore, points, weights, groups, members, gsize):
    """THE candidate-pass loop body (pending candidate pass at the top,
    then move + bound maintenance through ``core.reducer``) — the one
    copy every driver iterates: ``lax.while_loop`` in ``_run_loop`` and
    :func:`fit_core`, python-unrolled in the dry-run analysis variant.
    State is ``(EngineCarry, level_n, level_g)``; the ladder backend
    transitions its levels shard-locally via :func:`select_bucket`,
    every other backend carries constant zeros.

    With ``core.ring_iters > 0`` each body additionally writes one row
    of the telemetry ring (``repro.obs.ring`` layout) at its iteration
    index — a (C,) scatter into loop-carried state, no host traffic;
    ``core.live_drain`` adds a one-way ``io_callback`` per iteration."""

    def body(state):
        c, ln, lg = state
        with jax.named_scope("kpynq/candidate_pass"):
            new_as, new_ub, new_lb, pairs, gmax = core.candidate_pass(
                points, c.centroids, c.assignments, c.ub, c.lb, c.need,
                groups, members, gsize, x2=c.x2, c2=c.c2, level_n=ln,
                level_g=lg)
        with jax.named_scope("kpynq/move_and_bounds"):
            mv = move_and_bounds(
                points, c.centroids, new_as, new_ub, new_lb, groups,
                k=core.k, n_groups=core.n_groups, reducer=core.reducer,
                weights=weights, x2=c.x2, refresh=core.refresh_in_move)
        n_cand = jnp.sum(mv.need.astype(jnp.int32))
        ring = c.ring
        if core.ring_iters:
            with jax.named_scope("kpynq/ring_write"):
                cap_n, cap_g = _ring_caps(core, ln, lg, points.shape[0])
                proxy = mv.ub * mv.ub
                if weights is not None:
                    proxy = proxy * weights
                row = jnp.stack([
                    n_cand.astype(jnp.float32),
                    gmax.astype(jnp.float32),
                    mv.shift,
                    pairs + mv.tightened,
                    cap_n,
                    cap_g,
                    jnp.sum(proxy),
                    mv.tightened,
                ])
                ring = ring.at[c.iteration].set(row)
            if core.live_drain:
                io_callback(_obs_ring.emit_ring_row, None, c.iteration,
                            row, ordered=False)
        carry = EngineCarry(c.iteration + 1, mv.centroids, mv.c2, new_as,
                            mv.ub, mv.lb, c.x2, mv.need, n_cand, gmax,
                            mv.shift, c.evals.add(pairs).add(mv.tightened),
                            ring)
        if core.backend == "ladder":
            ln, lg = select_bucket(n_cand, gmax, ln, lg,
                                   cap_ns=core.cap_ns, cap_gs=core.cap_gs,
                                   down_n=core.down_n, down_g=core.down_g)
        return carry, ln, lg

    return body


def _loop_cond(core: PassCore, *, max_iters, tol, min_cap=0,
               allow_downshift=False):
    """The loop condition matching :func:`_loop_body`. Terminal exits
    (converged / out of iterations) for every backend — with a psum
    reducer the centroid sums are replicated, so ``shift`` agrees on
    every shard and the collectives stay in lockstep. The host-bucketed
    compact backend additionally exits when the pending candidate count
    leaves its bucket (or a strictly smaller bucket would fit), which
    is the batch driver's ONLY host sync."""

    def cond(state):
        c, _, _ = state
        active = jnp.logical_and(c.iteration < max_iters, c.shift > tol)
        if core.backend != "compact":
            return active
        fits = jnp.logical_and(c.n_cand <= core.cap_n,
                               c.gmax <= core.cap_g)
        ok = jnp.logical_and(active, fits)
        if allow_downshift and (core.down_n or core.down_g):
            # exit when a strictly smaller point bucket would fit — the
            # candidate pass is linear in cap_n, so one sync (~ms) buys
            # back every decay-phase iteration's padding. The group cap
            # only affects the bucketed pass's minor axis; chase it
            # lazily to avoid segment churn. The factors are the tuned
            # hysteresis (EngineConfig.down_n / down_g; 0 disables).
            down = jnp.bool_(False)
            if core.down_n:
                down = jnp.logical_or(down, jnp.logical_and(
                    c.n_cand * core.down_n <= core.cap_n,
                    core.cap_n > min_cap))
            if core.down_g:
                # gmax == 0 means the last pass saw no candidates, not
                # that one group slot suffices — never downshift on it
                down = jnp.logical_or(down, jnp.logical_and(
                    jnp.logical_and(c.gmax > 0,
                                    c.gmax * core.down_g <= core.cap_g),
                    core.cap_g > 1))
            ok = jnp.logical_and(ok, jnp.logical_not(down))
        return ok

    return cond


@functools.partial(jax.jit, static_argnames=(
    "core", "max_iters", "tol", "min_cap", "allow_downshift"))
def _run_loop(points, weights, carry, groups, members, gsize, *, core,
              max_iters, tol, min_cap, allow_downshift):
    """One capacity bucket's worth of device-resident iterations.

    Exits when converged / out of iterations (terminal), or — compact
    backend only — when the pending candidate count leaves its bucket
    ((cap/2, cap] for points, (cap/4, cap] for group slots), at which
    point the host picks the next bucket from the exit scalars. That
    is the ONLY host sync."""
    carry, _, _ = jax.lax.while_loop(
        _loop_cond(core, max_iters=max_iters, tol=tol, min_cap=min_cap,
                   allow_downshift=allow_downshift),
        _loop_body(core, points, weights, groups, members, gsize),
        (carry, jnp.int32(0), jnp.int32(0)))
    return carry


def _epilogue_pass(core: PassCore, points, weights, valid, carry, groups,
                   members, gsize, level_n, level_g):
    """Final pending candidate pass + (weighted) inertia — the traced
    tail shared by `_epilogue` and :func:`fit_core`. ``valid`` masks
    sentinel padding rows of an uneven sharded fit (their assignment is
    K; clip the gather and zero their cost).

    Returns ``(new_as, evals, inertia, ring)`` — the ring gains its
    final row at index ``carry.iteration``: the epilogue pass's evals
    and, in the inertia-proxy column, the EXACT (shard-local,
    pre-reduction) inertia."""
    with jax.named_scope("kpynq/candidate_pass"):
        new_as, _, _, pairs, _ = core.candidate_pass(
            points, carry.centroids, carry.assignments, carry.ub, carry.lb,
            carry.need, groups, members, gsize, x2=carry.x2, c2=carry.c2,
            level_n=level_n, level_g=level_g)
    evals = core.reducer.add(carry.evals.add(pairs).total())
    own = carry.centroids[jnp.minimum(new_as, core.k - 1)]
    d = rowwise_dists(points, own)
    d2 = d * d
    if valid is not None:
        d2 = jnp.where(valid, d2, 0.0)
    if weights is not None:
        d2 = d2 * weights
    local_inertia = jnp.sum(d2)
    inertia = core.reducer.add(local_inertia)
    ring = carry.ring
    if core.ring_iters:
        with jax.named_scope("kpynq/ring_write"):
            cap_n, cap_g = _ring_caps(core, level_n, level_g,
                                      points.shape[0])
            row = jnp.stack([
                carry.n_cand.astype(jnp.float32),
                carry.gmax.astype(jnp.float32),
                carry.shift,
                pairs,
                cap_n,
                cap_g,
                local_inertia,
                jnp.float32(0.0),
            ])
            ring = ring.at[carry.iteration].set(row)
        if core.live_drain:
            io_callback(_obs_ring.emit_ring_row, None, carry.iteration,
                        row, ordered=False)
    return new_as, evals, inertia, ring


@functools.partial(jax.jit, static_argnames=("core",))
def _epilogue(points, weights, carry, groups, members, gsize, *, core):
    """Final pending candidate pass + inertia, fused into one program."""
    return _epilogue_pass(core, points, weights, None, carry, groups,
                          members, gsize, jnp.int32(0), jnp.int32(0))


def fit_core(points, init_c, groups, members, gsize, *, core: PassCore,
             max_iters: int, tol: float, weights=None, valid=None):
    """The WHOLE fit — init, candidate-pass loop, epilogue — as one
    traced function with zero host syncs: the driver body shared by the
    fused small-problem path (local reducer, full static caps) and the
    ``shard_map`` body in :mod:`repro.core.distributed` (psum reducer +
    ladder backend). ``valid`` masks sentinel padding rows of an uneven
    sharded fit (assignment K drops out of every segment_sum; ub=0 /
    lb=inf keeps them filtered forever, and their K initial distance
    rows are taken back out of the eval count); ``weights`` are
    per-point sample weights (see :func:`move_and_bounds`).

    Returns ``(centroids, assignments, n_iters, evals, inertia, ring)``
    — the ring is the (core.ring_iters, C) telemetry buffer (shape
    (0, C) when disabled), SHARD-LOCAL under ``shard_map``.
    """
    k = core.k
    carry = _init_carry(points, init_c, groups, n_groups=core.n_groups,
                        ring_iters=core.ring_iters)
    if valid is not None:
        pad = jnp.sum(1.0 - valid.astype(jnp.float32))
        carry = carry._replace(
            assignments=jnp.where(valid, carry.assignments, k),
            ub=jnp.where(valid, carry.ub, 0.0),
            lb=jnp.where(valid[:, None], carry.lb, jnp.inf),
            evals=carry.evals.add(-pad * k))
    state = (carry, jnp.int32(0), jnp.int32(0))
    carry, ln, lg = jax.lax.while_loop(
        _loop_cond(core, max_iters=max_iters, tol=tol),
        _loop_body(core, points, weights, groups, members, gsize), state)
    new_as, evals, inertia, ring = _epilogue_pass(
        core, points, weights, valid, carry, groups, members, gsize, ln,
        lg)
    return carry.centroids, new_as, carry.iteration, evals, inertia, ring


def fit_core_unrolled(points, init_c, groups, members, gsize, *,
                      core: PassCore, n_iters: int, weights=None):
    """:func:`fit_core` with the while_loop replaced by exactly
    ``n_iters`` python iterations of the SAME :func:`_loop_body` —
    analysis artifacts only (XLA cost_analysis does not descend into
    while bodies; the N-vs-(N-1) unrolled diff gives the exact
    per-iteration cost)."""
    carry = _init_carry(points, init_c, groups, n_groups=core.n_groups,
                        ring_iters=core.ring_iters)
    state = (carry, jnp.int32(0), jnp.int32(0))
    body = _loop_body(core, points, weights, groups, members, gsize)
    for _ in range(n_iters):
        state = body(state)
    carry, ln, lg = state
    new_as, evals, inertia, ring = _epilogue_pass(
        core, points, weights, None, carry, groups, members, gsize, ln,
        lg)
    return carry.centroids, new_as, carry.iteration, evals, inertia, ring


@functools.partial(jax.jit, static_argnames=("n_groups", "ring_iters"))
def _init_carry(points, init_c, groups, *, n_groups, ring_iters=0):
    """Fused setup: point norms (THE once-per-fit ``||x||^2``), initial
    filter state, and the initial loop carry — one dispatch instead of
    the ~8 eager ops the old driver issued per fit. ``ring_iters``
    sizes the telemetry ring (0 = disabled, a (0, C) array that makes
    every ring op in the loop free)."""
    n = points.shape[0]
    x2 = row_norms_sq(points)
    c2 = row_norms_sq(init_c.astype(jnp.float32))
    state0 = _init_filter_state(points, init_c, groups, n_groups,
                                x2=x2, c2=c2)
    return EngineCarry(
        jnp.int32(0), state0.centroids, c2, state0.assignments, state0.ub,
        state0.lb, x2, jnp.zeros((n,), bool), jnp.int32(0), jnp.int32(0),
        jnp.float32(jnp.inf), state0.distance_evals,
        jnp.zeros((ring_iters, N_COUNTERS), jnp.float32))


@functools.partial(jax.jit, static_argnames=("core", "max_iters", "tol"))
def _fit_fused(points, init_c, weights, *, core, max_iters, tol):
    """Whole fit — grouping, init, loop, epilogue — as ONE program.

    Used for small problems (and exercised by tests for every backend):
    at a few thousand points the ~10 eager setup dispatches of the
    bucketed driver cost more than the entire fit, so run a single
    full-capacity segment with the group-membership table built on
    device (Lmax = K upper bound; fine at small K). Reuses
    :func:`fit_core` — at full capacities the loop's bucket conditions
    are vacuous, so the whole fit inlines to one program."""
    k, n_groups = core.k, core.n_groups
    groups = group_centroids(init_c, n_groups)
    # device-side (G, K) membership table: row g lists group g's
    # centroids in ascending order, -1-padded
    order = jnp.argsort(groups, stable=True)
    sg = groups[order]
    starts = jnp.searchsorted(sg, jnp.arange(n_groups))
    rank = jnp.arange(k) - starts[sg]
    members = jnp.full((n_groups, k), -1, jnp.int32).at[
        sg, rank].set(order.astype(jnp.int32))
    gsize = jax.ops.segment_sum(jnp.ones((k,), jnp.float32), groups,
                                num_segments=n_groups)
    return fit_core(points, init_c, groups, members, gsize, core=core,
                    max_iters=max_iters, tol=tol, weights=weights)


def _bucket_cap(count: int, floor: int, ceil: int) -> int:
    """Smallest power-of-two >= count, clamped to [floor, ceil]. The
    lattice keeps the set of compiled programs small and reusable."""
    cap = 1 << (max(int(count), 1) - 1).bit_length()
    return max(min(cap, ceil), min(floor, ceil))


def build_assign_tables(centroids, n_groups: int | None = None):
    """Group map + host-built tables over FIXED centroids — THE one
    copy of the inference-side table recipe (K//10 group heuristic,
    clamp to K, :func:`group_centroids`, :func:`build_group_tables`),
    shared by :func:`assign` and the estimator caches.

    Returns ``(groups, members, gsize)``.
    """
    k = centroids.shape[0]
    if n_groups is None:
        n_groups = max(k // 10, 1)
    n_groups = int(min(max(n_groups, 1), k))
    groups = group_centroids(centroids, n_groups)
    groups_np = np.asarray(jax.device_get(groups))
    members, gsize = build_group_tables(groups_np, n_groups)
    return groups, members, gsize


def build_group_tables(groups_np: np.ndarray, n_groups: int):
    """Host-side group tables: (G, Lmax) -1-padded membership matrix +
    fp32 group sizes. Shared by the batch fit and the streaming step."""
    counts = np.bincount(groups_np, minlength=n_groups)
    l_max = max(int(counts.max()), 1)
    members_np = np.full((n_groups, l_max), -1, np.int32)
    for g in range(n_groups):
        ids = np.nonzero(groups_np == g)[0]
        members_np[g, :len(ids)] = ids
    return jnp.asarray(members_np), jnp.asarray(counts.astype(np.float32))


def _resolve_config(*, backend, tile_n, min_cap, chunk, config, tune,
                    n, k, d):
    """Resolve the effective :class:`EngineConfig` for this fit.

    Precedence per knob: explicit ``fit`` kwarg > explicit ``config``
    object > tuned cache entry (``tune != "off"``) > built-in default.
    The caller's ``backend`` always wins unless it is ``"auto"``.
    Returns ``(config, resolved_backend)`` where the backend may be
    ``"lloyd"``.
    """
    cfg = DEFAULT_CONFIG
    if config is None and tune != "off":
        # "force" has already run the search by the time we get here
        # (fit() materialises it into an explicit config); both active
        # modes consult the persistent cache.
        from .. import tune as _tune
        cfg = _tune.lookup(n=n, k=k, d=d) or cfg
    if config is not None:
        cfg = config
    over = {}
    if tile_n is not None:
        over["tile_n"] = int(tile_n)
    if min_cap is not None:
        over["min_cap"] = int(min_cap)
    if chunk is not None:
        over["chunk"] = int(chunk)
    if over:
        cfg = cfg.replace(**over)

    resolved = backend
    if resolved == "auto":
        resolved = cfg.backend
    if resolved == "auto":
        if n * k <= cfg.lloyd_max_work:
            resolved = "lloyd"
        else:
            resolved = "pallas" if jax.default_backend() == "tpu" \
                else "compact"
    return cfg, resolved


def _publish_fit(obs_cfg, stats: EngineStats, result) -> None:
    """Publish one finished fit into the configured metrics registry —
    counters + an ``engine_fit`` event carrying the ring summary. Host
    python on already-fetched values; runs only under ``obs=``."""
    reg = obs_cfg.resolve_registry()
    labels = {"backend": stats.backend}
    reg.counter("engine_fits_total", "completed engine fits",
                labels=labels).inc()
    reg.counter("engine_distance_evals_total",
                "distance evaluations across fits", labels=labels).inc(
        float(result.distance_evals))
    reg.gauge("engine_last_n_iters", "iterations of the last fit",
              labels=labels).set(float(stats.n_iters))
    reg.gauge("engine_last_host_syncs", "host syncs of the last fit",
              labels=labels).set(float(stats.host_syncs))
    evt = {"backend": stats.backend, "n_iters": stats.n_iters,
           "host_syncs": stats.host_syncs, "n_points": stats.n_points,
           "distance_evals": float(result.distance_evals),
           "inertia": float(result.inertia)}
    tel = stats.telemetry()
    if tel is not None:
        evt["telemetry"] = tel
    reg.log_event("engine_fit", **evt)


def fit(points, init_centroids, *, n_groups: int | None = None,
        max_iters: int = 100, tol: float = 1e-4, backend: str = "auto",
        tile_n: int | None = None, min_cap: int | None = None,
        chunk: int | None = None, interpret: bool | None = None,
        max_bucket_switches: int = 32, return_stats: bool = False,
        config: EngineConfig | None = None, tune: str = "auto",
        sample_weight=None, obs=None):
    """Run filtered K-means fully device-resident.

    See the module docstring for backend semantics. ``interpret=None``
    auto-enables Pallas interpreter mode off-TPU, so
    ``backend='pallas'`` works (slowly) anywhere.

    ``config`` pins an explicit :class:`EngineConfig`; ``tune``
    controls the per-(platform, N, K, D) autotuning cache
    (:mod:`repro.tune`): ``"auto"`` (default) uses a cached winner when
    one exists, ``"force"`` additionally runs the measured search on a
    cache miss and persists the result, ``"off"`` uses built-in
    defaults. Tuning changes wall-clock only — assignments and inertia
    are bit-identical across configurations. Individual kwargs
    (``tile_n``/``min_cap``/``chunk``) override both.

    ``sample_weight``: optional (N,) per-point weights, entering the
    centroid sums and the inertia only (bounds and filter decisions
    are weight-independent). ``None`` compiles the exact pre-weight
    program; uniform weights of 1.0 are bit-identical to it.

    ``obs``: observability switch (see :mod:`repro.obs`) — ``None`` /
    ``False`` disabled (the exact pre-obs program compiles), ``True``
    defaults, a ``MetricsRegistry`` or ``ObsConfig`` for control. When
    enabled, the per-iteration telemetry ring rides the loop carry and
    is drained ONCE at exit into ``EngineStats.ring``
    (``host_syncs`` is unchanged — the drain rides the exit fetch),
    and the fit publishes counters + an ``engine_fit`` event into the
    registry. Results are bit-identical with obs on or off.

    Returns a :class:`~repro.core.kmeans.KMeansResult`; with
    ``return_stats=True`` returns ``(result, EngineStats)``.
    """
    if backend not in BACKENDS + ("auto", "lloyd"):
        raise ValueError(f"unknown engine backend {backend!r}; "
                         f"expected one of "
                         f"{BACKENDS + ('auto', 'lloyd')}")
    if tune not in ("auto", "off", "force"):
        raise ValueError(f"unknown tune mode {tune!r}; expected "
                         f"'auto', 'off' or 'force'")
    points = jnp.asarray(points)
    init_c = jnp.asarray(init_centroids)
    if init_c.dtype != jnp.float32:
        init_c = init_c.astype(jnp.float32)
    k = init_c.shape[0]
    n, d = points.shape
    weights = None if sample_weight is None else \
        jnp.asarray(sample_weight, jnp.float32)
    obs_cfg = normalize_obs(obs)
    ring_iters = int(max_iters) + 1 if obs_cfg and obs_cfg.ring else 0
    live_drain = bool(obs_cfg and obs_cfg.live_drain and ring_iters)

    if tune == "force" and config is None:
        from .. import tune as _tune
        config = _tune.get_or_tune(
            points, init_c, n_groups=n_groups, max_iters=int(max_iters),
            tol=float(tol))
    cfg, backend = _resolve_config(
        backend=backend, tile_n=tile_n, min_cap=min_cap, chunk=chunk,
        config=config, tune=tune, n=n, k=k, d=d)

    if backend == "lloyd":
        res = _lloyd_jit(points, init_c, weights, max_iters=int(max_iters),
                         tol=float(tol))
        if not return_stats and obs_cfg is None:
            return res              # keep the tiny-problem route lean:
                                    # no stats blocking / dict building
        stats = EngineStats(backend="lloyd", n_iters=int(res.n_iters),
                            host_syncs=1, config=cfg.to_dict(),
                            n_points=n)
        if obs_cfg is not None:
            # the dense loop has no filter pass, hence no ring — the
            # registry still gets the fit event/counters
            _publish_fit(obs_cfg, stats, res)
        return (res, stats) if return_stats else res
    if interpret is None:
        interpret = backend == "pallas" and jax.default_backend() != "tpu"
    if n_groups is None:
        n_groups = max(k // 10, 1)
    n_groups = int(min(n_groups, k))
    tol = float(tol)

    stats = EngineStats(backend=backend, x2_evals=1, config=cfg.to_dict(),
                        n_points=n)
    cap_floor = min(cfg.min_cap, n)

    def _core(cap_n, cap_g, l_max):
        ug = use_groups_decision(
            cap_n=cap_n, cap_g=cap_g, l_max=l_max, k=k, chunk=cfg.chunk,
            group_gather_factor=cfg.group_gather_factor) \
            if backend == "compact" else None
        return PassCore.from_config(
            cfg, backend=backend, k=k, n_groups=n_groups, cap_n=cap_n,
            cap_g=cap_g, use_groups=ug, interpret=bool(interpret),
            ring_iters=ring_iters, live_drain=live_drain)

    def _drain_ring(ring):
        # one device_get at fit exit — rides the exit fetch the driver
        # does anyway, so host_syncs stays exactly as without obs
        stats.ring = np.asarray(jax.device_get(ring))[:stats.n_iters + 1]
        stats.init_evals = float(n) * k

    if n <= 4 * cap_floor:
        # small problem: eager setup + bucket churn costs more than the
        # whole fit — run the fully-fused single-program path
        core = _core(n, n_groups, k)
        c, a, it, evals, inertia, ring = _fit_fused(
            points, init_c, weights, core=core, max_iters=int(max_iters),
            tol=tol)
        stats.host_syncs = 1
        stats.n_iters = int(it)
        if backend == "compact":
            stats.caps_history.append((n, n_groups))
            stats.use_groups.append(bool(core.use_groups))
        result = KMeansResult(c, a, it, evals, inertia)
        if ring_iters:
            _drain_ring(ring)
        if obs_cfg is not None:
            _publish_fit(obs_cfg, stats, result)
        return (result, stats) if return_stats else result

    groups = group_centroids(init_c, n_groups)

    # group membership table (G, Lmax), -1-padded; one setup-time sync
    groups_np = np.asarray(jax.device_get(groups))
    stats.host_syncs += 1
    members, gsize = build_group_tables(groups_np, n_groups)
    l_max = int(members.shape[1])

    carry = _init_carry(points, init_c, groups, n_groups=n_groups,
                        ring_iters=ring_iters)

    # start tiny: the first loop body's pending candidate pass is empty
    # (carry.need = 0), so a full-capacity program would burn one whole
    # dense pass on padding. The first real candidate count exits the
    # loop after iteration 1 and picks the right bucket.
    cap_n, cap_g = cap_floor, 1
    while True:
        core = _core(cap_n, cap_g, l_max)
        stats.caps_history.append((cap_n, cap_g))
        if backend == "compact":
            stats.use_groups.append(bool(core.use_groups))
        allow_down = stats.bucket_switches < max_bucket_switches
        carry = _run_loop(points, weights, carry, groups, members, gsize,
                          core=core, max_iters=int(max_iters), tol=tol,
                          min_cap=cap_floor, allow_downshift=allow_down)
        it, nc, gm, sh = jax.device_get(
            (carry.iteration, carry.n_cand, carry.gmax, carry.shift))
        stats.host_syncs += 1
        if int(it) >= max_iters or float(sh) <= tol:
            break
        if backend != "compact":          # single-trace backends never
            break                         # exit the loop non-terminally
        stats.bucket_switches += 1
        if stats.bucket_switches >= max_bucket_switches:
            cap_n, cap_g = _bucket_cap(n, cap_floor, n), n_groups
        else:
            cap_n = _bucket_cap(int(nc), cap_floor, n)
            # gmax == 0 means no candidate pass has run at this bucket
            # yet (the opening probe segment): guess the full group
            # count rather than burning a whole segment discovering it
            cap_g = _bucket_cap(int(gm), 1, n_groups) if int(gm) > 0 \
                else n_groups
    stats.n_iters = int(it)

    # epilogue: the final iteration's pending candidate pass + inertia.
    # Caps only key the compact pass; pin them for the single-trace
    # backends so the epilogue compiles exactly once.
    if backend == "compact":
        ecap_n = _bucket_cap(int(nc), cap_floor, n)
        ecap_g = _bucket_cap(int(gm), 1, n_groups)
    else:
        ecap_n, ecap_g = n, n_groups
    assignments, evals, inertia, ring = _epilogue(
        points, weights, carry, groups, members, gsize,
        core=_core(ecap_n, ecap_g, l_max))

    result = KMeansResult(carry.centroids, assignments, carry.iteration,
                          evals, inertia)
    if ring_iters:
        _drain_ring(ring)
    if obs_cfg is not None:
        _publish_fit(obs_cfg, stats, result)
    if return_stats:
        return result, stats
    return result


# --------------------------------------------------------------------------
# streaming / mini-batch single-pass step (driven by repro.streaming)
# --------------------------------------------------------------------------

class StreamStepOut(NamedTuple):
    """Outputs of one mini-batch :func:`stream_step`. The
    returned ``ub``/``lb`` are already decayed by this step's centroid
    drift, i.e. valid against the RETURNED centroids — exactly what the
    caller's per-shard bound cache wants to store."""
    centroids: jnp.ndarray    # (K, D) after the decayed update
    counts: jnp.ndarray       # (K,) decayed effective counts
    assignments: jnp.ndarray  # (B,)
    ub: jnp.ndarray           # (B,) post-move upper bounds
    lb: jnp.ndarray           # (B, G) post-move lower bounds
    pairs: jnp.ndarray        # f32: point-centroid pairs scored
    gmax: jnp.ndarray         # int32: surviving-group high-water
    drift: jnp.ndarray        # (K,) this step's per-centroid drift
    gdrift: jnp.ndarray       # (G,) this step's per-group max drift
    batch_counts: jnp.ndarray  # (K,) points of THIS batch per centroid
    batch_cost: jnp.ndarray   # f32 sum(ub^2) pre-move: an upper-bound
                              # estimate of the batch's inertia


@jax.jit
def stream_bounds(points, centroids, assignments, ub, lb):
    """Point-level filter over CARRIED (drift-inflated) bounds — the
    first half of ``move_and_bounds`` without the centroid move. ``ub``
    must upper-bound d(x, centroids[assignments]) and ``lb`` must
    lower-bound the per-group min excluding the assignment (the shard
    cache's :func:`repro.streaming.inflate_bounds` contract).

    Returns ``(ub_t, need, n_cand, n_tightened)``: tightened upper
    bounds, the pending candidate mask, its popcount, and how many
    exact own-centroid distances were spent tightening.
    """
    glb = jnp.min(lb, axis=1)
    maybe = ub > glb
    d_own = rowwise_dists(points, centroids[assignments])
    ub_t = jnp.where(maybe, d_own, ub)
    need = ub_t > glb
    return ub_t, need, jnp.sum(need.astype(jnp.int32)), jnp.sum(
        maybe.astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("core",))
def stream_step(points, centroids, counts, decay, groups, members, gsize,
                assignments, ub_t, lb, need, weights=None, *,
                core: PassCore):
    """One mini-batch against EXTERNAL carry (centroids + effective
    counts): the PassCore candidate pass, then the decayed
    count-weighted centroid EMA (:data:`EMA_UPDATE` through
    :func:`move_and_bounds`), then post-move bound decay — the same
    pass + epilogue pieces as the batch drivers, instantiated with the
    streaming update rule.

    This is the reusable single-pass step behind
    :class:`repro.streaming.StreamingKMeans`; with a psum
    ``core.reducer`` it is also the body of the sharded step
    (``repro.core.distributed.make_stream_update_sharded``): the
    reducer joins the batch sums/counts so the EMA (and drift) come
    out replicated, and reduces the scalar telemetry
    (``pairs``/``gmax``/``batch_cost``).

    ``core.cap_n`` MUST be >= the (per-shard) candidate count (the
    caller syncs it via :func:`stream_bounds`); ``core.cap_g`` is a
    guess — the pass's ``lax.cond`` spills to the dense branch when it
    is exceeded, and the returned ``gmax`` recalibrates the next
    visit. ``weights``: optional per-point sample weights entering the
    batch sums/counts (the EMA's effective mass) and the batch cost.

    Sentinel-padded rows (sharded caller) carry assignment K: the
    traced drift gather clamps, and the caller slices their ub/lb off.
    """
    x2 = row_norms_sq(points)                 # once per batch
    c2 = row_norms_sq(centroids)
    new_as, nub, nlb, pairs, gmax = core.candidate_pass(
        points, centroids, assignments, ub_t, lb, need, groups, members,
        gsize, x2=x2, c2=c2)
    mv = move_and_bounds(
        points, centroids, new_as, nub, nlb, groups, k=core.k,
        n_groups=core.n_groups, reducer=core.reducer, update=EMA_UPDATE,
        counts=counts, decay=decay, weights=weights, refresh=False)
    cost = nub * nub if weights is None else weights * nub * nub
    return StreamStepOut(mv.centroids, mv.counts, new_as, mv.ub, mv.lb,
                         core.reducer.add(pairs), core.reducer.max(gmax),
                         mv.drift, mv.gdrift, mv.batch_counts,
                         core.reducer.add(jnp.sum(cost)))


# --------------------------------------------------------------------------
# tiled assignment (predict / transform / score drive this)
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("core",))
def _assign_tile(points, centroids, c2, groups, members, gsize, *,
                 core: PassCore):
    """Exact nearest-centroid assignment of ONE tile through the
    PassCore candidate pass with vacuous bounds — norm-cached
    (``c2`` once per assign, ``x2`` per tile), never materialising an
    (N, K) matrix beyond the tile."""
    b = points.shape[0]
    x2 = row_norms_sq(points)
    a0 = jnp.zeros((b,), jnp.int32)
    ub = jnp.full((b,), jnp.inf, jnp.float32)
    lb = jnp.zeros((b, core.n_groups), jnp.float32)
    need = jnp.ones((b,), bool)
    nas, nub, _, pairs, _ = core.candidate_pass(
        points, centroids, a0, ub, lb, need, groups, members, gsize,
        x2=x2, c2=c2)
    return nas, nub, pairs


def assign(points, centroids, *, n_groups: int | None = None,
           groups=None, members=None, gsize=None, tile_n: int = 8192,
           chunk: int = 2048, group_gather_factor: int = 4):
    """Tiled exact nearest-centroid assignment against fixed centroids.

    The inference-side counterpart of the fit drivers: each ``tile_n``
    slice of ``points`` runs the PassCore compact candidate pass with
    vacuous bounds, so no O(N*K) distance buffer ever exists (the
    per-tile working set is (tile_n, K)) and the centroid norms are
    computed once for the whole call. ``KMeans.predict`` /
    ``StreamingKMeans.predict`` / ``score`` all land here.

    ``groups``/``members``/``gsize`` may be passed when the caller
    already holds the group tables (the streaming estimator does);
    otherwise they are built from the centroids (``n_groups`` defaults
    to the K//10 heuristic).

    Returns ``(labels, dists)``: (N,) int32 assignments and (N,) f32
    exact distances to the assigned centroid.
    """
    points = jnp.asarray(points)
    if points.dtype != jnp.float32:
        points = points.astype(jnp.float32)
    centroids = jnp.asarray(centroids)
    if centroids.dtype != jnp.float32:
        centroids = centroids.astype(jnp.float32)
    n = points.shape[0]
    k = centroids.shape[0]
    if n == 0:
        return (jnp.zeros((0,), jnp.int32), jnp.zeros((0,), jnp.float32))
    if groups is None:
        groups, members, gsize = build_assign_tables(centroids, n_groups)
    n_groups = int(gsize.shape[0])

    c2 = row_norms_sq(centroids)
    tile = min(_bucket_cap(min(tile_n, n), 1, n), n)
    core = PassCore(backend="compact", k=k, n_groups=n_groups,
                    cap_n=tile, cap_g=n_groups, chunk=chunk,
                    group_gather_factor=group_gather_factor)
    labels, dists = [], []
    for lo in range(0, n, tile):
        part = points[lo:lo + tile]
        if part.shape[0] < tile:      # pad the ragged tail tile so the
            part = jnp.pad(           # per-tile program compiles once
                part, ((0, tile - part.shape[0]), (0, 0)))
        nas, nub, _ = _assign_tile(part, centroids, c2, groups, members,
                                   gsize, core=core)
        labels.append(nas)
        dists.append(nub)
    labels = jnp.concatenate(labels)[:n]
    dists = jnp.concatenate(dists)[:n]
    return labels, dists


# --------------------------------------------------------------------------
# serve-side batched assignment (repro.serve drives this)
# --------------------------------------------------------------------------
#
# The serving hot path differs from `assign` in three ways:
#
# * centroids/norms are RUNTIME ARGUMENTS, not trace constants — the
#   double-buffered epoch swap (repro.serve.CentroidIndex) republishes
#   centroids continuously, and a publish must never recompile. The
#   compiled-program cache is keyed on the query bucket shape only.
# * the reduction is the min-trick, not argmin: XLA's row-wise argmin
#   does not vectorise when reducing the minor axis on CPU (it costs
#   ~8x the distance GEMM at K=64); `min` does. Two vectorised min
#   passes — the distance minimum, then the smallest index attaining
#   it — reproduce argmin's first-match semantics exactly, so labels
#   stay bit-identical to the dense oracle.
# * batches arrive pre-padded to a pow2 bucket, so there is no ragged
#   tail handling here; `lax.map` over `chunk`-point tiles keeps the
#   per-tile (chunk, K) working set cache-resident.

def _serve_fused_impl(q, centroids, c2, *, chunk: int = 1024):
    """Fused dense batched assignment: norm-cached distance GEMM +
    min-trick label reduction, tiled by ``chunk``. Exact (bit-identical
    to ``argmin`` of the dense distance matrix). Returns (B,) int32."""
    k = centroids.shape[0]
    iota = jnp.arange(k, dtype=jnp.int32)

    def tile_fn(qt):
        # ||x||^2 omitted: constant per row, argmin-invariant
        d2 = c2[None, :] - 2.0 * (qt @ centroids.T)
        mn = jnp.min(d2, axis=1, keepdims=True)
        return jnp.min(jnp.where(d2 <= mn, iota[None, :], k),
                       axis=1).astype(jnp.int32)

    b, d = q.shape
    if b > chunk and b % chunk == 0:
        return jax.lax.map(tile_fn, q.reshape(-1, chunk, d)).reshape(-1)
    return tile_fn(q)


serve_assign_fused = jax.jit(_serve_fused_impl,
                             static_argnames=("chunk",))
# donated variant: the query buffer is dead after the labels are read,
# so accelerators may reuse it in place. No-op on CPU (jax warns), so
# make_serve_assign only routes here off-CPU.
serve_assign_fused_donated = jax.jit(_serve_fused_impl,
                                     static_argnames=("chunk",),
                                     donate_argnums=(0,))


@functools.partial(jax.jit, static_argnames=("core",))
def serve_assign_grouped(q, centroids, c2, groups, members, gsize, *,
                         core: PassCore):
    """Group-table batched assignment: the PassCore candidate pass with
    vacuous bounds (the same pass `assign` tiles), with centroids and
    group tables as runtime args so epoch swaps never recompile. The
    ``pallas`` backend routes to the ``grouped_assign`` block-skip
    kernel. Returns (B,) int32."""
    b = q.shape[0]
    x2 = row_norms_sq(q)
    a0 = jnp.zeros((b,), jnp.int32)
    ub = jnp.full((b,), jnp.inf, jnp.float32)
    lb = jnp.zeros((b, core.n_groups), jnp.float32)
    need = jnp.ones((b,), bool)
    nas, _, _, _, _ = core.candidate_pass(
        q, centroids, a0, ub, lb, need, groups, members, gsize,
        x2=x2, c2=c2)
    return nas


def make_serve_assign(snapshot_shape, *, backend: str = "fused",
                      chunk: int = 1024, interpret: bool = False,
                      donate: bool | None = None):
    """Resolve the serve-side batched assign callable for a centroid
    snapshot shape ``(k, n_groups)``.

    Returns ``fn(q, centroids, c2, groups, members, gsize) -> labels``
    — a uniform signature over all backends (the fused path ignores
    the tables). ``backend``: ``"fused"`` (dense GEMM + min-trick, the
    CPU winner), ``"grouped"`` (PassCore compact pass over the group
    tables), or ``"pallas"`` (the block-skip kernel; ``interpret=True``
    off-TPU). All three are exact. ``donate`` (default: on except CPU,
    where donation is a no-op) donates the query buffer on the fused
    path — off-CPU this INVALIDATES a ``jax.Array`` the caller passes
    in ("Array has been deleted" on its next use), so only enable it
    for buffers the caller is done with; ``ServeEngine`` donates its
    own staging transfers and passes ``donate=False`` for client-owned
    device arrays on the exact-fit path."""
    k, n_groups = snapshot_shape
    if donate is None:
        donate = jax.default_backend() != "cpu"
    if backend == "fused":
        fused = serve_assign_fused_donated if donate \
            else serve_assign_fused

        def run(q, centroids, c2, groups=None, members=None, gsize=None):
            return fused(q, centroids, c2, chunk=chunk)
        run.cache_size = fused._cache_size
        return run
    if backend not in ("grouped", "pallas"):
        raise ValueError(f"unknown serve backend {backend!r}")
    pc_backend = "pallas" if backend == "pallas" else "compact"

    def run(q, centroids, c2, groups, members, gsize):
        core = PassCore(backend=pc_backend, k=k, n_groups=n_groups,
                        cap_n=q.shape[0], cap_g=n_groups, chunk=chunk,
                        interpret=interpret)
        return serve_assign_grouped(q, centroids, c2, groups, members,
                                    gsize, core=core)
    run.cache_size = serve_assign_grouped._cache_size
    return run
