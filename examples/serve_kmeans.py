"""Live k-means serving: a streaming fit publishing into a served index.

Two threads, one index:

* a **fitter** drives ``StreamingKMeans.fit_stream`` over a sharded
  point stream with ``attach_index(index)`` — every committed
  mini-batch publishes fresh centroids into the double-buffered
  :class:`repro.serve.CentroidIndex` (group tables rebuilt or reused on
  the drift ledger's word);
* the main thread runs a :class:`repro.serve.ServeEngine` front-end,
  submitting ragged query blocks while the fit is still running. Each
  response carries the exact epoch that labelled it, so the refresh is
  visible as the epoch climbs mid-traffic.

  PYTHONPATH=src python examples/serve_kmeans.py [--smoke]
"""
import argparse
import threading
import time

import numpy as np

from repro.data import PointStream, make_points
from repro.serve import CentroidIndex, ServeEngine
from repro.streaming import StreamingKMeans
from repro.tune import ServeConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes + short traffic (CI)")
    ap.add_argument("--k", type=int, default=32)
    ap.add_argument("--dims", type=int, default=16)
    ap.add_argument("--shards", type=int, default=None,
                    help="stream length (default 24, 8 with --smoke)")
    args = ap.parse_args(argv)
    shards = args.shards or (8 if args.smoke else 24)

    stream = PointStream(512, n_shards=shards, n_dims=args.dims,
                         k=args.k, seed=0)
    index = CentroidIndex(rebuild_threshold=0.05)
    skm = StreamingKMeans(args.k, seed=0,
                          init_size=1024).attach_index(index)

    # the stream as a deterministic batch list so the fit can be split:
    # the first shards run synchronously (init + jit compiles land
    # before traffic starts — on a small box the background thread
    # would otherwise spend the whole demo compiling), the rest refresh
    # the index live under load
    batches = [stream.global_batch(i) for i in range(shards)]
    warm = max(2, -(-1024 // 512))
    skm.fit_stream(batches[:warm])
    fitter = threading.Thread(
        target=lambda: skm.fit_stream(batches[warm:]), daemon=True)

    queries, _, _ = make_points(8192, args.dims, args.k, seed=7)
    queries = np.ascontiguousarray(queries, np.float32)
    cfg = ServeConfig(max_batch=4096)
    rng = np.random.default_rng(3)
    served = 0
    epochs_seen = []
    with ServeEngine(index, config=cfg, tune="off") as eng:
        # compile the serve bucket lattice before the clock starts
        b = cfg.min_bucket
        while b <= cfg.max_batch:
            eng.assign(queries[:b])
            b *= 2
        fitter.start()
        t0 = time.perf_counter()
        # open-loop-ish traffic while the fit is live: ragged blocks,
        # a breather between requests so the fitter shares the core
        deadline = t0 + (4.0 if args.smoke else 10.0)
        while time.perf_counter() < deadline:
            m = int(rng.integers(64, 2048))
            lo = int(rng.integers(0, queries.shape[0] - m))
            labels, epoch = eng.assign(queries[lo:lo + m])
            served += labels.shape[0]
            if not epochs_seen or epoch != epochs_seen[-1]:
                epochs_seen.append(epoch)
                print(f"[serve] epoch -> {epoch} "
                      f"(rebuilds={index.rebuilds} reuses={index.reuses})")
            if not fitter.is_alive() and len(epochs_seen) > 1:
                break
            time.sleep(0.002)
        elapsed = time.perf_counter() - t0
    fitter.join(timeout=60)
    pps = served / max(elapsed, 1e-9)
    print(f"[serve] {served} points in {elapsed * 1e3:.0f}ms "
          f"({pps:.0f} pts/s) across epochs {epochs_seen} "
          f"(publishes={index.publishes})")
    return served


if __name__ == "__main__":
    main()
