"""Batched serving example: prefill + decode with KV/SSM caches, plus
the KPynq KV-cache clustering integration for long contexts.

  PYTHONPATH=src python examples/serve_lm.py [--arch mamba2-780m]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.integrations import (cluster_kv_cache,
                                     clustered_attention_scores)
from repro.launch.serve import main as serve_main


def kv_clustering_demo():
    """Approximate attention over a clustered KV cache: score error vs
    exact attention at 8x memory compression."""
    rng = jax.random.PRNGKey(0)
    s, h, dh, k = 512, 4, 32, 64
    keys = jax.random.normal(rng, (s, h, dh)) + \
        jnp.repeat(jax.random.normal(jax.random.PRNGKey(1), (8, h, dh)) * 3,
                   s // 8, axis=0)       # clustered structure
    # values correlated with keys (as in trained models) — the
    # regime where within-cluster value averaging is faithful
    vals = 0.9 * keys + 0.1 * jax.random.normal(
        jax.random.PRNGKey(2), (s, h, dh))
    # query aligned with one key cluster (the realistic regime:
    # decode attention is concentrated, which is what clustering
    # preserves well)
    q = keys[10] + 0.1 * jax.random.normal(jax.random.PRNGKey(3), (h, dh))
    scale = 1.0 / np.sqrt(dh)

    kc, vc, counts = cluster_kv_cache(keys, vals, k)
    probs_c = clustered_attention_scores(q, kc, counts, scale)   # (H, K)
    out_c = jnp.einsum("hk,khd->hd", probs_c, vc)

    scores = jnp.einsum("hd,shd->hs", q, keys) * scale
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("hs,shd->hd", probs, vals)

    err = float(jnp.linalg.norm(out - out_c) / jnp.linalg.norm(out))
    print(f"[kv_clustering] {s} keys -> {k} centroids "
          f"({s / k:.0f}x compression): attention output rel-err {err:.3f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-780m")
    args = ap.parse_args()
    print("== batched prefill+decode ==")
    serve_main(["--arch", args.arch, "--reduced", "--batch", "4",
                "--prompt-len", "32", "--gen-len", "16"])
    print("== KPynq KV-cache clustering (long-context approximation) ==")
    kv_clustering_demo()


if __name__ == "__main__":
    main()
