"""Quickstart: KPynq K-means in five lines.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import KMeans
from repro.data import make_points

# 100k points, 32-dim, 64 true clusters
points, _, _ = make_points(100_000, 32, 64, seed=0)

km = KMeans(n_clusters=64, algorithm="yinyang").fit(points)       # KPynq
km_ref = KMeans(n_clusters=64, algorithm="lloyd").fit(points)     # baseline

print(f"inertia  kpynq={km.inertia_:.1f} lloyd={km_ref.inertia_:.1f}")
print(f"iters    kpynq={km.n_iter_} lloyd={km_ref.n_iter_}")
print(f"distance evaluations: kpynq={km.distance_evals_:.3g} "
      f"lloyd={km_ref.distance_evals_:.3g} "
      f"-> work reduction {km_ref.distance_evals_ / km.distance_evals_:.1f}x")
assert np.allclose(km.inertia_, km_ref.inertia_, rtol=1e-4), \
    "filters are exact: same clustering, less work"
print("OK — identical clustering, fraction of the work.")
