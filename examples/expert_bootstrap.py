"""KPynq inside the LM stack: K-means-bootstrapped MoE routing.

The paper's fast K-means is used as a sub-system of MoE training:
expert router weights are initialised to centroid directions of the
token-embedding distribution, so experts start as owners of coherent
embedding-space regions. This example measures routing balance
(entropy / max-load) of kmeans-init vs random-init routers.

  PYTHONPATH=src python examples/expert_bootstrap.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.integrations import kmeans_router_init
from repro.models import init_params


def routing_stats(params, cfg, tokens):
    embeds = jnp.take(params["embed"], tokens.reshape(-1), axis=0)
    router = params["layers"]["moe"]["router"][0]           # layer 0
    logits = embeds.astype(jnp.float32) @ router.astype(jnp.float32)
    top1 = jnp.argmax(logits, axis=-1)
    counts = jnp.bincount(top1, length=cfg.n_experts)
    probs = counts / counts.sum()
    entropy = -jnp.sum(jnp.where(probs > 0, probs * jnp.log(probs), 0.0))
    return float(entropy), float(counts.max() / counts.mean())


def main():
    cfg = get_config("llama4-scout-17b-a16e").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 512),
                                0, cfg.vocab)

    ent_rand, load_rand = routing_stats(params, cfg, tokens)
    params_km = kmeans_router_init(params, cfg, tokens)
    ent_km, load_km = routing_stats(params_km, cfg, tokens)

    max_ent = np.log(cfg.n_experts)
    print(f"[expert_bootstrap] experts={cfg.n_experts} "
          f"(max entropy {max_ent:.2f})")
    print(f"  random router: entropy={ent_rand:.3f} "
          f"max/mean load={load_rand:.2f}")
    print(f"  kmeans router: entropy={ent_km:.3f} "
          f"max/mean load={load_km:.2f}")
    print("  -> kmeans init gives experts coherent embedding regions "
          "at near-balanced load")


if __name__ == "__main__":
    main()
