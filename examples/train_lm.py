"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps
on a learnable synthetic corpus, with fault-tolerant checkpointing.

  PYTHONPATH=src python examples/train_lm.py                # ~100M params
  PYTHONPATH=src python examples/train_lm.py --tiny --steps 60   # CI-size

The corpus is a deterministic affine token chain (t+1 = 7*t+3 mod V)
so the loss measurably collapses once the model memorises the map —
a real end-to-end learning signal, not noise-fitting.
"""
import argparse
import dataclasses

import jax
import numpy as np

from repro.configs.base import ArchConfig
from repro.data import TokenPipeline
from repro.optim.adamw import AdamWConfig
from repro.runtime import ResilientLoop
from repro.train.steps import init_train_state, make_train_step


def lm_100m() -> ArchConfig:
    # ~102M params: 12L, d=768, 12H, ff=3072, vocab=8192 (GPT-2-small-ish)
    return ArchConfig(
        name="repro-lm-100m", family="dense", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=12, head_dim=64, d_ff=3072, vocab=8192,
        q_chunk=256, loss_chunk=256, dtype="float32", remat="none")


def lm_tiny() -> ArchConfig:
    return dataclasses.replace(lm_100m(), n_layers=2, d_model=128,
                               n_heads=4, n_kv_heads=4, head_dim=32,
                               d_ff=512, vocab=512, name="repro-lm-tiny")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    cfg = lm_tiny() if args.tiny else lm_100m()
    from repro.models.transformer import param_shapes
    n_params = sum(int(np.prod(s)) for s in jax.tree.leaves(
        param_shapes(cfg), is_leaf=lambda x: isinstance(x, tuple)))
    print(f"[train_lm] {cfg.name}: {n_params / 1e6:.1f}M params")

    seq = [0]
    for _ in range(200_000):
        seq.append((seq[-1] * 7 + 3) % cfg.vocab)
    corpus = np.asarray(seq, dtype=np.int32)

    pipeline = TokenPipeline(cfg, args.batch, args.seq, seed=0,
                             corpus=corpus)
    opt = AdamWConfig(lr_peak=1e-3, warmup_steps=max(args.steps // 10, 10),
                      decay_steps=args.steps)
    step_fn = jax.jit(make_train_step(cfg, opt), donate_argnums=0)
    state = init_train_state(jax.random.PRNGKey(0), cfg)

    loop = ResilientLoop(step_fn, pipeline, args.ckpt_dir,
                         ckpt_every=max(args.steps // 4, 25))
    loop.run(state, args.steps)
    losses = [m["loss"] for m in loop.metrics_log]
    n = max(len(losses) // 10, 1)
    print(f"[train_lm] loss: start={np.mean(losses[:n]):.3f} "
          f"end={np.mean(losses[-n:]):.3f} "
          f"({np.mean(losses[:n]) / max(np.mean(losses[-n:]), 1e-9):.1f}x drop)")
    print(f"[train_lm] mean step time "
          f"{np.mean([m['dt'] for m in loop.metrics_log[2:]]) * 1e3:.0f} ms")


if __name__ == "__main__":
    main()
