"""The paper's workload end-to-end: UCI-like suite + distributed run.

  PYTHONPATH=src python examples/kmeans_clustering.py [--scale 0.25]

Runs the KPynq algorithm (multi-level filter), the point-level-only
variant, the stream-compaction execution mode, and — on a multi-device
runtime — the shard_map data-parallel version, reporting work reduction
for each (the paper's Table, reproduced at whatever scale fits the
machine).
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs.kpynq import paper_suite
from repro.core import (distributed_yinyang, kmeans_plusplus, lloyd,
                        yinyang, yinyang_compact)
from repro.data import make_points


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.1)
    ap.add_argument("--max-datasets", type=int, default=4)
    args = ap.parse_args()

    print(f"{'dataset':12s} {'N':>9s} {'D':>4s} {'K':>5s} "
          f"{'iters':>5s} {'work_red':>9s} {'hamerly':>8s}")
    for prob in paper_suite[:args.max_datasets]:
        n = max(int(prob.n_points * args.scale), 1024)
        pts_np, _, _ = make_points(n, prob.n_dims, prob.k, seed=0)
        pts = jnp.asarray(pts_np)
        init = kmeans_plusplus(jax.random.PRNGKey(1), pts, prob.k)
        r_l = lloyd(pts, init, prob.max_iters, prob.tol)
        r_y = yinyang(pts, init, max_iters=prob.max_iters, tol=prob.tol)
        r_h = yinyang(pts, init, n_groups=1, max_iters=prob.max_iters,
                      tol=prob.tol)
        wr = float(r_l.distance_evals) / float(r_y.distance_evals)
        wh = float(r_l.distance_evals) / float(r_h.distance_evals)
        print(f"{prob.name:12s} {n:9d} {prob.n_dims:4d} {prob.k:5d} "
              f"{int(r_y.n_iters):5d} {wr:8.1f}x {wh:7.1f}x")

    # compaction mode (real wall-clock saving on CPU)
    pts_np, _, _ = make_points(32768, 32, 256, seed=0)
    pts = jnp.asarray(pts_np)
    init = kmeans_plusplus(jax.random.PRNGKey(1), pts, 256)
    r_c = yinyang_compact(pts, init, max_iters=40)
    print(f"\ncompaction mode: iters={int(r_c.n_iters)} "
          f"evals={float(r_c.distance_evals):.3g} "
          f"inertia={float(r_c.inertia):.1f}")

    # distributed (shard_map) — uses however many devices exist
    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev,), ("data",))
    r_d = distributed_yinyang(pts, init, mesh, max_iters=40)
    print(f"distributed ({n_dev} devices): inertia={float(r_d.inertia):.1f} "
          f"matches single-device: "
          f"{abs(float(r_d.inertia) - float(r_c.inertia)) / float(r_c.inertia) < 1e-4}")


if __name__ == "__main__":
    main()
