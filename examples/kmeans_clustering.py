"""The paper's workload end-to-end: UCI-like suite + distributed run.

  PYTHONPATH=src python examples/kmeans_clustering.py [--scale 0.25]

Runs the KPynq algorithm (multi-level filter), the point-level-only
variant, the stream-compaction execution mode, the STREAMING mini-batch
fit (bound-carrying ``partial_fit`` over deterministic shards — the
never-in-memory-at-once path), and — on a multi-device runtime — the
shard_map data-parallel version, reporting work reduction for each
(the paper's Table, reproduced at whatever scale fits the machine).
Also demos the observability layer: an engine fit with the telemetry
ring on, printing the per-iteration filter-efficiency table (see
``docs/observability.md``).

Streaming decay schedule: ``StreamingKMeans(decay=1.0)`` (used here) is
pure count-weighting — per-centroid 1/n learning rates, converging to
the batch fit on stationary data; ``decay<1`` forgets with a
~1/(1-decay)-batch horizon for drifting streams.
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs.kpynq import paper_suite
from repro.core import (distributed_yinyang, kmeans_plusplus, lloyd,
                        yinyang, yinyang_compact)
from repro.data import PointStream, make_points
from repro.streaming import StreamingKMeans


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.1)
    ap.add_argument("--max-datasets", type=int, default=4)
    args = ap.parse_args()

    print(f"{'dataset':12s} {'N':>9s} {'D':>4s} {'K':>5s} "
          f"{'iters':>5s} {'work_red':>9s} {'hamerly':>8s}")
    for prob in paper_suite[:args.max_datasets]:
        n = max(int(prob.n_points * args.scale), 1024)
        pts_np, _, _ = make_points(n, prob.n_dims, prob.k, seed=0)
        pts = jnp.asarray(pts_np)
        init = kmeans_plusplus(jax.random.PRNGKey(1), pts, prob.k)
        r_l = lloyd(pts, init, prob.max_iters, prob.tol)
        r_y = yinyang(pts, init, max_iters=prob.max_iters, tol=prob.tol)
        r_h = yinyang(pts, init, n_groups=1, max_iters=prob.max_iters,
                      tol=prob.tol)
        wr = float(r_l.distance_evals) / float(r_y.distance_evals)
        wh = float(r_l.distance_evals) / float(r_h.distance_evals)
        print(f"{prob.name:12s} {n:9d} {prob.n_dims:4d} {prob.k:5d} "
              f"{int(r_y.n_iters):5d} {wr:8.1f}x {wh:7.1f}x")

    # compaction mode (real wall-clock saving on CPU)
    pts_np, _, _ = make_points(32768, 32, 256, seed=0)
    pts = jnp.asarray(pts_np)
    init = kmeans_plusplus(jax.random.PRNGKey(1), pts, 256)
    r_c = yinyang_compact(pts, init, max_iters=40)
    print(f"\ncompaction mode: iters={int(r_c.n_iters)} "
          f"evals={float(r_c.distance_evals):.3g} "
          f"inertia={float(r_c.inertia):.1f}")

    # observability: the same problem through the engine with the
    # telemetry ring on — the device records per-iteration filter
    # efficiency (candidates surviving, evals spent, active capacity
    # bucket, drift) with ZERO extra host syncs, drained once at exit.
    # Results are bit-identical with the ring on or off.
    from repro.core import engine_fit
    from repro.obs import ObsConfig, format_ring_table
    _, stats = engine_fit(pts, init, max_iters=40, backend="compact",
                          tune="off", return_stats=True,
                          obs=ObsConfig())
    print("\nper-iteration filter efficiency (telemetry ring):")
    print(format_ring_table(stats.ring, stats.n_points, max_rows=12))
    print(f"telemetry: {stats.telemetry()}")

    # streaming / mini-batch: the SAME dataset as the compaction demo,
    # fed as 2048-point shards through partial_fit. Epochs 2+ revisit
    # shards, so the per-shard triangle-inequality bounds (inflated by
    # accumulated centroid drift) skip most of the distance work —
    # watch cache_hits and the work reduction vs a dense mini-batch
    # pass.
    stream = PointStream(shard_size=2048, data=pts_np)
    skm = StreamingKMeans(256, seed=1, init_size=4096)
    skm.fit_stream(stream, epochs=3)
    st = skm.stats_
    gap = skm.inertia_of(pts_np) / float(r_c.inertia) - 1.0
    print(f"streaming fit: batches={st.batches} "
          f"cache_hits={st.cache_hits} reseeds={st.reseeds} "
          f"work_red={st.points_seen * 256 / max(st.distance_evals, 1):.1f}x "
          f"inertia gap vs batch: {gap * 100:+.2f}%")

    # weighted clustering: sample_weight threads through every backend
    # and driver via the one PassCore implementation — uniform weights
    # are bit-identical to the unweighted fit, and integer weights are
    # exactly equivalent to duplicating points (cheaper by the weight
    # mass). Demo: upweight the first blob 5x and watch its centroid
    # mass grow without touching the filter work.
    import numpy as np
    from repro.core import KMeans
    km = KMeans(n_clusters=8, engine="auto", seed=1)
    sub = np.asarray(pts_np[:8192])
    w = np.where(np.arange(len(sub)) < 1024, 5.0, 1.0).astype(np.float32)
    km.fit(sub, sample_weight=w)
    print(f"weighted fit: inertia={km.inertia_:.1f} "
          f"score(training)={km.score(sub, sample_weight=w):.1f}")

    # the predict path: tiled PassCore assignment — no (N, K) distance
    # matrix, norm-cached, exact. transform() gives the sklearn
    # cluster-distance space (tiled too), fit_predict the one-call fit.
    labels = km.predict(sub)
    print(f"predict (tiled): {len(labels)} labels, "
          f"first tile matches transform argmin: "
          f"{bool((labels[:100] == km.transform(sub[:100]).argmin(1)).all())}")

    # distributed (shard_map) — uses however many devices exist
    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev,), ("data",))
    r_d = distributed_yinyang(pts, init, mesh, max_iters=40)
    print(f"distributed ({n_dev} devices): inertia={float(r_d.inertia):.1f} "
          f"matches single-device: "
          f"{abs(float(r_d.inertia) - float(r_c.inertia)) / float(r_c.inertia) < 1e-4}")


if __name__ == "__main__":
    main()
